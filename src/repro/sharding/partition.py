"""Logical-axis sharding rules: param-path -> PartitionSpec.

Strategy (``tensor``, the dry-run default):
  * batch over ``data`` (x ``pod`` when multi-pod)  — the paper's DP axis
  * weights tensor-parallel over ``model``          — d_ff / heads / vocab
  * giant archs additionally FSDP the other big dim over ``data``
  * MoE experts: expert dim over ``model`` (EP)
  * DiLoCo outer sync runs over ``pod`` only (see core/diloco.py)

Rules are *name-based* on the '/'-joined param path, mirroring how MaxText &
friends do logical-axis annotation, but without a flax dependency.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import tree_map_with_path_str


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Axis naming + divisibility decisions for one (arch x mesh) lowering.

    ``None`` mesh_axes anywhere in the model code means 'single device, no
    constraints' (CPU smoke tests).
    """
    batch: tuple[str, ...] = ("data",)     # ("pod","data") when multi-pod
    model: str = "model"
    data: str = "data"
    pod: Optional[str] = None
    # attention head sharding is only used when head counts divide the axis
    shard_q_heads: bool = True
    shard_kv_heads: bool = True
    # reshard activations to batch x (data, model) for attention when heads
    # don't divide (qwen3-14b 40H, llava 56H on a 16-wide model axis)
    attn_batch_reshard: bool = False
    fsdp: bool = False
    model_axis_size: int = 1
    data_axis_size: int = 1
    # concrete mesh, needed by shard_map-based layers (MoE EP, pipeline);
    # excluded from __eq__/__hash__ inputs via compare=False so MeshAxes stays
    # usable as a static jit argument.
    mesh: Optional[Mesh] = dataclasses.field(default=None, compare=False)

    @property
    def all_batch(self) -> tuple[str, ...]:
        return self.batch

    @property
    def batch_shard_total(self) -> int:
        """Product of batch-axis sizes (how many ways the batch splits)."""
        if self.mesh is None:
            return self.data_axis_size
        return int(np.prod([self.mesh.shape[a] for a in self.batch]))


def make_mesh_axes(mesh: Mesh, model_cfg, parallel_cfg) -> MeshAxes:
    names = mesh.axis_names
    multi_pod = "pod" in names
    model_size = mesh.shape["model"]
    data_size = mesh.shape["data"]
    n_heads, n_kv = model_cfg.n_heads, model_cfg.n_kv_heads
    # q heads always shard over the model axis: when the head count doesn't
    # divide (qwen3 40H, llava 56H on a 16-wide axis) GSPMD pads — measured
    # ~10x cheaper than resharding activations batch-wise (probe log)
    shard_q = n_heads >= model_size
    shard_kv = n_kv % model_size == 0
    batch = ("pod", "data") if multi_pod else ("data",)
    return MeshAxes(
        batch=batch,
        pod="pod" if multi_pod else None,
        shard_q_heads=shard_q,
        shard_kv_heads=shard_kv,
        attn_batch_reshard=False,
        fsdp=parallel_cfg.fsdp,
        model_axis_size=model_size,
        data_axis_size=data_size,
        mesh=mesh,
    )


def shard_constraint(x: jax.Array, spec: Optional[P]) -> jax.Array:
    """with_sharding_constraint that is a no-op outside a mesh context."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (single-device smoke path)


def batch_spec(ma: Optional[MeshAxes], *trailing: Any) -> Optional[P]:
    if ma is None:
        return None
    return P(ma.all_batch, *trailing)


# ---------------------------------------------------------------------------
# Param rules
# ---------------------------------------------------------------------------

# Each entry: (path regex, builder(ma) -> spec per-dim tuple). Evaluated in
# order; first match wins. `_` stands for None (replicated dim).


def _rules(ma: MeshAxes) -> list[tuple[str, Sequence[Any]]]:
    fsdp = ma.data if ma.fsdp else None
    mdl = ma.model
    q = mdl if ma.shard_q_heads else None
    kv = mdl if ma.shard_kv_heads else None
    return [
        # embeddings / unembeddings: (padded_vocab, d_model) — vocab over model
        (r"(^|/)embed(/|$)|(^|/)unembed(/|$)", (mdl, fsdp)),
        # MoE expert banks: (n_experts, d_in, d_out) — EP over model, FSDP d_in
        (r"/experts?/.*(w_gate|w_up)$|/experts?/w_in$", (mdl, fsdp, None)),
        (r"/experts?/w_out$", (mdl, None, fsdp)),
        (r"/router/", (fsdp, None)),
        # attention projections (leading scan dim handled by caller)
        (r"/attn/wq$", (fsdp, q)),
        (r"/attn/(wk|wv)$", (fsdp, kv)),
        (r"/attn/wo$", (q, fsdp)),
        # dense FFN (SwiGLU)
        (r"/mlp/(w_gate|w_up)$", (fsdp, mdl)),
        (r"/mlp/w_out$", (mdl, fsdp)),
        # bottleneck compressors: tiny — replicate
        (r"/bottleneck", (None, None)),
        # mamba: in/out projections are the big ones
        (r"/mamba/in_proj$", (fsdp, mdl)),
        (r"/mamba/out_proj$", (mdl, fsdp)),
        (r"/mamba/", (None, None)),
        # xlstm: per-head gate projections (d, H) are tiny — replicate
        (r"/(mlstm|slstm)/(wgi|wgf)$", (None, None)),
        # xlstm: qkv/gate/proj matrices over model
        (r"/(mlstm|slstm)/(wq|wk|wv|w[izfo])$", (fsdp, mdl)),
        (r"/(mlstm|slstm)/(up_proj)$", (fsdp, mdl)),
        (r"/(mlstm|slstm)/(down_proj)$", (mdl, fsdp)),
        (r"/(mlstm|slstm)/r[izfo]$", (None, None)),
        # norms / scalars / biases: replicated
        (r".*", ()),
    ]


def _spec_for(path: str, ndim: int, has_scan_dim: bool, ma: MeshAxes) -> P:
    for pattern, dims in _rules(ma):
        if re.search(pattern, path):
            dims = list(dims)
            break
    else:  # pragma: no cover
        dims = []
    if has_scan_dim and ndim > 0:
        dims = [None] + dims            # leading layers/period dim: replicated
    # pad/trim to ndim
    dims = (dims + [None] * ndim)[:ndim]
    return P(*dims)


_SCAN_MARKERS = ("blocks/", "layers/", "period/", "enc_blocks/", "dec_blocks/")


def param_specs(params_or_shapes, ma: Optional[MeshAxes]):
    """PartitionSpec pytree matching the param tree.

    Parameters stacked for scan-over-layers (any path containing a
    ``blocks/``-style marker) get a leading replicated dim.
    """
    if ma is None:
        return jax.tree.map(lambda _: P(), params_or_shapes)

    def rule(path: str, leaf):
        ndim = len(leaf.shape)
        scanned = any(m in path for m in _SCAN_MARKERS)
        return _spec_for(path, ndim, scanned, ma)

    return tree_map_with_path_str(rule, params_or_shapes)


def param_shardings(params_or_shapes, mesh: Mesh, ma: Optional[MeshAxes]):
    specs = param_specs(params_or_shapes, ma)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
