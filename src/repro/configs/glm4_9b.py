"""glm4-9b [dense] — RoPE, GQA kv=2 — [hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,        # kv heads replicated over the model axis
        d_ff=13696,
        vocab_size=151552,
        rope_theta=10000.0,
    ),
    parallel=ParallelConfig(grad_accum=16, fsdp=True),
    source="hf:THUDM/glm-4-9b; hf",
)
