"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table) —

[arXiv:2501.kimi2; unverified].

Memory plan (why this config differs from the defaults): ~1.03T params.
bf16 masters + Adafactor factored stats + FSDP over (data x model) is the
only way a 1T model approaches v5e HBM: params 2 TB + grads 2 TB at step
peak = 4 TB ≈ the ENTIRE 256-chip pod HBM (4.1 TB), so single-pod train_4k
is reported as over-budget in EXPERIMENTS.md §Dry-run and the multi-pod
(512-chip) mesh is the fitting configuration.
"""
from repro.configs.base import ArchConfig, ModelConfig, MoEConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,           # per-expert FFN width
        vocab_size=163840,
        moe=MoEConfig(n_experts=384, top_k=8, int8_fsdp_gather=True),
        rope_theta=50000.0,
    ),
    parallel=ParallelConfig(
        grad_accum=8,
        fsdp=True,
        optimizer="adafactor",
        param_dtype="bfloat16",
    ),
    source="arXiv:2501.kimi2; unverified",
)
