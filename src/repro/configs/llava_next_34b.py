"""llava-next-34b [vlm] — anyres tiling — [hf:llava-hf/llava-v1.6-mistral-7b-hf;

unverified].  Backbone only per the assignment; the ViT frontend is a stub
(``input_specs`` feeds 2880 = 5 tiles x 576 precomputed patch embeddings).
"""
from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig
from repro.models.frontends import LLAVA_FRONTEND_TOKENS

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,          # 56 % 16 != 0 -> attention uses batch-reshard
        n_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        frontend_tokens=LLAVA_FRONTEND_TOKENS,
        rope_theta=5_000_000.0,
    ),
    parallel=ParallelConfig(grad_accum=16, fsdp=True),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
