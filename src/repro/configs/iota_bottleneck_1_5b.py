"""iota-bottleneck-1.5b — the PAPER'S OWN reference model (§4, Fig 5).

'Modified Llama3.2-1.5B': 16 layers, d_model 2048, with 3 bottleneck blocks
of width 32 — the paper's headline 128x case (fp32 basis: 2048*32 bits ->
32*16 bits).  This config is the subject of the convergence benchmark
(benchmarks/bench_convergence.py) and the pipeline-strategy perf cell.
"""
from repro.configs.base import (
    ArchConfig,
    BottleneckConfig,
    ModelConfig,
    ParallelConfig,
)

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="iota-bottleneck-1.5b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
        bottleneck=BottleneckConfig(n_bottlenecks=3, bottleneck_dim=32),
    ),
    parallel=ParallelConfig(grad_accum=1),
    source="paper §4 (Llama3.2-1.5B + 3 bottlenecks, 128x)",
)
