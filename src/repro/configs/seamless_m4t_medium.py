"""seamless-m4t-medium [audio] — enc-dec, multimodal — [arXiv:2308.11596; hf].

n_layers=12 applies to BOTH stacks (12 encoder + 12 decoder).  The speech
frontend is a stub: the encoder consumes precomputed fbank-conv frame
embeddings (B, F, 1024); F = seq_len/4 capped at 4096 (DESIGN.md).
vocab 256206 is padded to 256512 for even model-axis sharding
(Megatron-style; padded logits masked to -inf).
"""
from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="seamless-m4t-medium",
        family="audio",
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        is_encoder_decoder=True,
    ),
    parallel=ParallelConfig(grad_accum=8),
    source="arXiv:2308.11596; hf",
)
