"""qwen3-14b [dense] — qk_norm, GQA — [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,          # 40 % 16 != 0 -> attention uses batch-reshard
        n_kv_heads=8,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
    ),
    parallel=ParallelConfig(grad_accum=16, fsdp=True),
    source="hf:Qwen/Qwen3-8B; hf",
)
