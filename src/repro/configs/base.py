"""Config system: model / compression / parallelism / train / shape configs.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG: ArchConfig``.  ``registry.get("qwen3-14b")`` resolves them, and
``--arch`` flags on the launchers go through the registry.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional, Sequence

from repro.common import round_up

# ---------------------------------------------------------------------------
# Model family tags (assignment families)
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
VLM = "vlm"
AUDIO = "audio"
HYBRID = "hybrid"

FAMILIES = (DENSE, MOE, SSM, VLM, AUDIO, HYBRID)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # Capacity factor for the padded per-device expert buffers in the EP path.
    capacity_factor: float = 1.25
    # Which layers carry an MoE FFN.  "all" | "alternate" (jamba-style: odd
    # layers MoE, even dense).
    layer_pattern: str = "all"
    router_jitter: float = 0.0
    # FSDP expert gathers ride int8 with per-row scales + straight-through
    # backward (§Perf cell A iteration 2 — halves the dominant collective
    # term of trillion-param MoE training; beyond-paper, in the spirit of
    # the paper's compressed-sharing stage)
    int8_fsdp_gather: bool = False


@dataclasses.dataclass(frozen=True)
class BottleneckConfig:
    """Paper §4: bottleneck transformer blocks with uninterrupted residual flow.

    ``n_bottlenecks`` bottleneck/post-bottleneck pairs are inserted at equally
    spaced block boundaries.  ``bottleneck_dim`` is the compressed activation
    width streamed across the boundary (32 → 64x dim reduction on a 2048-d
    model; with bf16-on-wire that is the paper's 128x vs fp32).
    ``residual_alpha`` is the learned-initialisation weight of the partial
    residual fed into/out of the bottleneck hidden (Fig 4).
    """
    n_bottlenecks: int = 0
    bottleneck_dim: int = 32
    residual_alpha: float = 0.5

    @property
    def enabled(self) -> bool:
        return self.n_bottlenecks > 0

    def compression_ratio(self, d_model: int, wire_bits: int = 16) -> float:
        """Compression vs the paper's fp32/d_model basis."""
        return (d_model * 32) / (self.bottleneck_dim * wire_bits)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                      # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    bottleneck: BottleneckConfig = dataclasses.field(default_factory=BottleneckConfig)
    # --- family-specific knobs ---
    # hybrid (jamba): period layout; within each period of `hybrid_period`
    # blocks, block index `hybrid_attn_index` is attention, the rest Mamba.
    hybrid_period: int = 8
    hybrid_attn_index: int = 4
    # ssm (xlstm): alternation of mLSTM/sLSTM blocks; d_ff == 0 means the
    # blocks use their own up/down projections (proj_factor).
    xlstm_proj_factor: float = 2.0
    # mamba block hyperparams (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # enc-dec (seamless): n_layers applies to BOTH encoder and decoder stacks.
    is_encoder_decoder: bool = False
    # vlm / audio frontends are stubs: input_specs() provides precomputed
    # frame/patch embeddings of width `frontend_embed_dim` == d_model.
    frontend_tokens: int = 0             # patches/frames prepended to the text
    # activations streamed between pipeline stages use this dtype on the wire
    # (paper: bf16 = 2x of fp32)
    max_seq_len: int = 1 << 20

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 512 so embedding/logit matrices shard

        evenly on a 16-wide model axis (Megatron-style vocab padding; padded
        logits are masked to -inf, padded embedding rows are zero-init)."""
        return round_up(self.vocab_size, 512)

    @property
    def uses_attention(self) -> bool:
        return self.family != SSM

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(1)/O(layer-subset) state at 500k ctx
        (shape rule: ``long_500k`` runs only for SSM/hybrid archs)."""
        return self.family in (SSM, HYBRID)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        return _param_count(self, active_only=True)


def _ffn_params(cfg: ModelConfig, active_only: bool) -> int:
    """Per-layer FFN params (SwiGLU: 3 matrices)."""
    dense_ffn = 3 * cfg.d_model * cfg.d_ff
    if cfg.moe is None:
        return dense_ffn
    n = cfg.moe.top_k if active_only else cfg.moe.n_experts
    expert = 3 * cfg.d_model * cfg.d_ff * n
    router = cfg.d_model * cfg.moe.n_experts
    if cfg.moe.layer_pattern == "alternate":
        # half the layers dense, half MoE -> return the *average* per layer
        return (dense_ffn + expert + router) // 2
    return expert + router


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.head_dim
    return (cfg.d_model * cfg.n_heads * hd          # wq
            + 2 * cfg.d_model * cfg.n_kv_heads * hd  # wk, wv
            + cfg.n_heads * hd * cfg.d_model)        # wo


def _mamba_params(cfg: ModelConfig) -> int:
    d_in = cfg.mamba_expand * cfg.d_model
    return (cfg.d_model * 2 * d_in                  # in_proj (x, z)
            + d_in * cfg.mamba_d_conv               # conv
            + d_in * (cfg.mamba_d_state * 2 + 1)    # B, C, dt proj (folded)
            + d_in * cfg.mamba_d_state              # A
            + d_in                                   # D
            + d_in * cfg.d_model)                   # out_proj


def _xlstm_params(cfg: ModelConfig) -> int:
    # mLSTM block: qkv + gates + up/down proj (proj_factor)
    d = cfg.d_model
    d_up = int(cfg.xlstm_proj_factor * d)
    mlstm = 3 * d * d + 2 * d + 2 * d * d_up + d_up * d
    slstm = 4 * d * d + 4 * d * d // max(cfg.n_heads, 1) + 2 * d * d_up + d_up * d
    return (mlstm + slstm) // 2  # alternating -> average per layer


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    embed = cfg.padded_vocab * cfg.d_model
    unembed = 0 if cfg.tie_embeddings else cfg.padded_vocab * cfg.d_model
    per_layer = 0
    if cfg.family == SSM:
        per_layer = _xlstm_params(cfg)
    elif cfg.family == HYBRID:
        n_attn = cfg.n_layers // cfg.hybrid_period
        n_mamba = cfg.n_layers - n_attn
        attn_side = n_attn * (_attn_params(cfg) + _ffn_params(cfg, active_only))
        mamba_side = n_mamba * (_mamba_params(cfg) + _ffn_params(cfg, active_only))
        total = attn_side + mamba_side + embed + unembed
        return total
    else:
        per_layer = _attn_params(cfg) + _ffn_params(cfg, active_only)
    n_stacks = 2 if cfg.is_encoder_decoder else 1
    cross = _attn_params(cfg) * cfg.n_layers if cfg.is_encoder_decoder else 0
    return embed + unembed + n_stacks * cfg.n_layers * per_layer + cross


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for the LM family)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    """Shape rule: long_500k only for sub-quadratic archs."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Parallelism / training configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a given (arch x shape) is laid out on the mesh."""
    strategy: str = "tensor"        # "tensor" (GSPMD TP+FSDP) | "pipeline"
    fsdp: bool = False              # shard params over the data axis too
    grad_accum: int = 1             # microbatch count (scan) per train step
    remat: bool = True              # activation checkpointing per block
    # pipeline strategy knobs
    pipeline_microbatches: int = 8
    # DiLoCo (paper §2.1): inner steps between outer merges, outer lr/momentum
    diloco_inner_steps: int = 64
    diloco_outer_lr: float = 0.7
    diloco_outer_momentum: float = 0.9
    # optimizer: "adamw" | "adafactor" (giant archs) | "sgdm"
    optimizer: str = "adamw"
    # dtype for optimizer 2nd-order state; bf16 halves optimizer HBM for
    # giant archs (noted in DESIGN.md hardware adaptation)
    opt_state_dtype: str = "float32"
    # master param dtype; "bfloat16" for the 1T-class archs where fp32 masters
    # cannot fit pod HBM (paired with adafactor + fp32 factored stats)
    param_dtype: str = "float32"
    # sequence-chunk size for recurrent scans (mamba/xlstm): outer scan over
    # chunks with a rematerialized inner scan bounds carry storage
    scan_chunk: int = 256
    # shard attention over q-heads when divisible; else batch-reshard scheme
    attn_batch_reshard: bool = False


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    z_loss: float = 1e-4            # logit regularizer, also stabilizes fp32 loss


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture: model + its default parallel/train configs."""
    model: ModelConfig
    parallel: ParallelConfig = dataclasses.field(default_factory=ParallelConfig)
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    source: str = ""                 # provenance tag from the assignment table

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS: tuple[str, ...] = (
    "stablelm-3b",
    "qwen3-14b",
    "glm4-9b",
    "llama3.2-1b",
    "kimi-k2-1t-a32b",
    "olmoe-1b-7b",
    "xlstm-125m",
    "llava-next-34b",
    "seamless-m4t-medium",
    "jamba-v0.1-52b",
    # the paper's own reference model (§4: bottleneck-Llama3.2-1.5B)
    "iota-bottleneck-1.5b",
)

_MODULE_FOR_ARCH = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR_ARCH)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ARCH[arch_id]}")
    cfg = mod.CONFIG
    assert cfg.model.arch_id == arch_id, (cfg.model.arch_id, arch_id)
    return cfg


def all_arch_ids(include_paper_ref: bool = False) -> list[str]:
    ids = [a for a in ARCH_IDS if a != "iota-bottleneck-1.5b"]
    if include_paper_ref:
        ids.append("iota-bottleneck-1.5b")
    return ids


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small layers/width,

    few experts, tiny vocab — one forward/train step must run on CPU."""
    m = cfg.model
    moe = None
    if m.moe is not None:
        moe = dataclasses.replace(
            m.moe, n_experts=min(8, m.moe.n_experts), top_k=min(2, m.moe.top_k))
    n_layers = max(2, min(4, m.n_layers))
    if m.family == HYBRID:
        n_layers = m.hybrid_period  # one full period keeps the interleave
    bott = m.bottleneck
    if bott.enabled:
        bott = dataclasses.replace(bott, n_bottlenecks=1, bottleneck_dim=8)
    small = dataclasses.replace(
        m,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(4, max(1, m.n_kv_heads * 4 // max(m.n_heads, 1))),
        d_head=16,
        d_ff=0 if m.d_ff == 0 else 128,
        vocab_size=512,
        moe=moe,
        bottleneck=bott,
        frontend_tokens=min(8, m.frontend_tokens),
        mamba_d_state=8,
    )
    par = dataclasses.replace(cfg.parallel, grad_accum=1, fsdp=False)
    return dataclasses.replace(cfg, model=small, parallel=par)
