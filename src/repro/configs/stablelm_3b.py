"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
    ),
    parallel=ParallelConfig(grad_accum=8),
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
