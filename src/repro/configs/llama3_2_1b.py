"""llama3.2-1b [dense] — small llama3 — [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
    ),
    parallel=ParallelConfig(grad_accum=8),
    source="hf:meta-llama/Llama-3.2-1B; unverified",
)
