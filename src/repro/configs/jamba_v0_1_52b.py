"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2 —

[arXiv:2403.19887; hf].  Period of 8 blocks: index 4 is attention, the rest
Mamba; MoE FFN on odd block indices (alternate), dense FFN on even.
Sub-quadratic (mamba state O(1); 4/32 attention layers keep a KV cache) =>
``long_500k`` runs for this arch.
"""
from repro.configs.base import ArchConfig, ModelConfig, MoEConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        moe=MoEConfig(n_experts=16, top_k=2, layer_pattern="alternate"),
        hybrid_period=8,
        hybrid_attn_index=4,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
    ),
    parallel=ParallelConfig(grad_accum=8, fsdp=True),
    source="arXiv:2403.19887; hf",
)
