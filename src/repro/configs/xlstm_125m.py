"""xlstm-125m [ssm] — sLSTM + mLSTM blocks — [arXiv:2405.04517; unverified].

d_ff = 0: the xLSTM blocks carry their own up/down projections
(proj_factor 2.0) instead of a separate FFN.  Sub-quadratic state =>
``long_500k`` runs for this arch.
"""
from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        arch_id="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        xlstm_proj_factor=2.0,
    ),
    parallel=ParallelConfig(grad_accum=4),
    source="arXiv:2405.04517; unverified",
)
