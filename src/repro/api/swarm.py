"""``Swarm`` — the thin facade over transport + phases + driver.

The seed ``Orchestrator`` monolith is now: construction (this class),
a message plane (``Transport``), and a timeline (``EpochDriver`` over
``Phase`` objects).  ``Orchestrator`` in ``repro.runtime.orchestrator``
subclasses this for backward compatibility.

    swarm = Swarm.create(model_cfg, SwarmConfig(seed=0))
    stats = swarm.run(3)

    net = Swarm.create(model_cfg, SwarmConfig(seed=0),
                       transport=SimulatedNetworkTransport(
                           NetworkModel.consumer()))
    net.run(3)
    net.transport.elapsed_seconds()   # simulated wall-clock

    # the same timeline with the store in ANOTHER PROCESS (real sockets,
    # serde wire format; examples/multiprocess_swarm.py is the runnable
    # version) — the trajectory is transport-invariant:
    proc, addr = spawn_store_server()
    remote = Swarm.create(model_cfg, SwarmConfig(seed=0),
                          transport=SocketTransport(addr))
    remote.run(3)
    remote.transport.traffic_report()  # server-side authoritative bytes
"""
from __future__ import annotations

from typing import Any, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import EpochStats, SwarmConfig
from repro.api.keys import KeySchema
from repro.api.phases import EpochDriver, Phase, sharded_phases
from repro.api.transport import InProcessTransport, Transport
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import diloco
from repro.core.incentives import IncentiveLedger
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.runtime import stage_model as sm
from repro.runtime.miner import Miner
from repro.runtime.network import FaultModel
from repro.runtime.validator import Validator


class Swarm:
    def __init__(self, model_cfg: ModelConfig, config: SwarmConfig,
                 faults: Optional[FaultModel] = None,
                 transport: Optional[Transport] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 driver: Optional[EpochDriver] = None):
        self.cfg = model_cfg
        self.config = config
        if transport is None:
            # sharded sync mints shard-level keys: needs KeySchema v2
            schema = KeySchema(version=2) \
                if config.sync_mode == "sharded" else KeySchema()
            transport = InProcessTransport(schema=schema)
        elif config.sync_mode == "sharded" and transport.schema.version < 2:
            raise ValueError(
                "sync_mode='sharded' needs a KeySchema v2 transport "
                f"(got v{transport.schema.version}); construct it with "
                "schema=KeySchema(version=2)")
        self.transport = transport
        self.faults = faults or FaultModel({}, seed=config.seed)
        self.spec = sm.SwarmModelSpec(model_cfg, config.n_stages,
                                      config.compress, config.bottleneck_dim)
        self.train_cfg = train_cfg or TrainConfig(lr=1e-3, warmup_steps=20)
        self.rng = np.random.RandomState(config.seed)
        self.ledger = IncentiveLedger(config.gamma_hours)
        self.corpus = SyntheticCorpus(DataConfig(
            vocab_size=model_cfg.vocab_size, seq_len=config.seq_len,
            batch_size=config.batch_size, seed=config.seed))
        if driver is None:
            # the sharded timeline appends the store-side reduce audit
            driver = EpochDriver(sharded_phases()) \
                if config.sync_mode == "sharded" else EpochDriver()
        self.driver = driver
        self.global_tick = 0
        self.epoch = 0

        # per-stage anchors + DiLoCo outer state (the shared model)
        key = jax.random.key(config.seed)
        self.anchors: list[Any] = []
        self.outer: list[diloco.OuterState] = []
        for s in range(config.n_stages):
            p = sm.init_stage_params(jax.random.fold_in(key, s), self.spec, s)
            self.anchors.append(p)
            self.outer.append(diloco.outer_init(p))

        # register miners: uid = stage * miners_per_stage + slot
        self.miners: dict[int, Miner] = {}
        for s in range(config.n_stages):
            for _ in range(config.miners_per_stage):
                self.register_miner(stage=s)

        self.validators = [Validator(v, self.transport, self.ledger)
                           for v in range(config.validators)]
        self.history: list[EpochStats] = []

    @classmethod
    def create(cls, model_cfg: ModelConfig,
               config: Optional[SwarmConfig] = None, *,
               faults: Optional[FaultModel] = None,
               transport: Optional[Transport] = None,
               train_cfg: Optional[TrainConfig] = None,
               phases: Optional[Iterable[Phase]] = None,
               runtime: str = "inprocess",
               store_address: Optional[tuple] = None,
               snapshot_root: Optional[str] = None,
               chaos: Any = None,
               store_standby: bool = False) -> "Swarm":
        """Build a swarm.  ``runtime="inprocess"`` (default) is the
        lockstep oracle; ``runtime="actors"`` returns an ``ActorSwarm``
        whose miners/validators are concurrent OS processes over a socket
        store (own threaded server unless ``store_address`` points at an
        external one) — same loss trajectory at the same seed, remember
        to ``shutdown()``.

        Chaos knobs (actors only — docs/CHAOS.md): ``snapshot_root``
        enables crash-resume snapshot caches, ``chaos`` (a
        ``runtime.chaos.FaultSchedule``) wraps every actor's transport
        in deterministic fault injection, ``store_standby`` runs a warm
        store replica with client-side failover."""
        config = config or SwarmConfig()
        # fail fast: compile the pipeline timetable these knobs describe
        # (schedule registry membership, microbatch/virtual-stage
        # divisibility) before any store or actor machinery spins up —
        # a bad combination should not surface mid-epoch in a subprocess
        from repro.core.pipeline import compile_timetable
        compile_timetable(config.pipeline_schedule, config.n_stages,
                          config.pipeline_microbatches,
                          config.pipeline_virtual_stages)
        if runtime == "actors":
            if phases is not None or transport is not None:
                raise ValueError(
                    "runtime='actors' owns its timeline and transport; "
                    "phases=/transport= only apply to the in-process "
                    "runtime")
            from repro.runtime.actor import ActorSwarm
            return ActorSwarm(model_cfg, config,
                              faults=faults, train_cfg=train_cfg,
                              store_address=store_address,
                              snapshot_root=snapshot_root,
                              chaos=chaos, store_standby=store_standby)
        if runtime != "inprocess":
            raise ValueError(
                f"unknown runtime {runtime!r}: 'inprocess' or 'actors'")
        if store_address is not None:
            raise ValueError(
                "store_address= only applies to runtime='actors'; pass "
                "transport=SocketTransport(address) for an in-process "
                "swarm over a socket store")
        if snapshot_root is not None or chaos is not None or store_standby:
            raise ValueError(
                "snapshot_root=/chaos=/store_standby= only apply to "
                "runtime='actors' (the chaos toolkit wraps actor "
                "processes; the lockstep oracle stays fault-free)")
        driver = EpochDriver(phases) if phases is not None else None
        return cls(model_cfg, config, faults=faults,
                   transport=transport, train_cfg=train_cfg, driver=driver)

    # ------------------------------------------------------------------

    @property
    def swarm(self) -> SwarmConfig:
        """Seed-era alias (``orch.swarm`` was the config attribute)."""
        return self.config

    @property
    def store(self):
        """The backing StateStore, when the transport has one in-process."""
        return getattr(self.transport, "store", None)

    def register_miner(self, stage: int) -> Miner:
        """Join at any time; actively participates after the next full sync

        (it is initialised from the anchor = 'copying existing miners'
        states', §2.2)."""
        uid = len(self.miners)
        params = jax.tree.map(jnp.copy, self.anchors[stage])
        m = Miner(uid, stage, self.spec, params, self.transport,
                  self.train_cfg)
        self.miners[uid] = m
        return m

    def stage_miners(self, stage: int) -> list[Miner]:
        return [m for m in self.miners.values() if m.stage == stage]

    def available(self, m: Miner, tick: int) -> bool:
        """Fault-model gate the TrainingPhase consults per (miner, tick).

        NOTE: draws from the fault RNG on every call — call order is part
        of the determinism contract."""
        b = self.faults.behavior(m.uid)
        if self.faults.is_dropped(m.uid):
            return False
        period = max(int(round(b.straggle_factor)), 1)
        return tick % period == 0

    # ------------------------------------------------------------------

    def run_epoch(self) -> EpochStats:
        return self.driver.run_epoch(self)

    def run(self, n_epochs: int) -> list[EpochStats]:
        return [self.run_epoch() for _ in range(n_epochs)]
