"""Typed peer-protocol messages (frozen dataclasses).

Everything miners, validators and the orchestrator exchange is one of these
five message types; a message knows its own store key via ``key(schema)``.
Payloads ride next to the envelope (``Transport.publish(msg, payload)``)
rather than inside it so the frozen envelope stays hashable and cheap to
log/replay.

The set mirrors the paper's traffic planes:
  ActivationMsg    forward wire codes (plus pipeline-entry tokens)
  GradientMsg      backward wire gradients
  WeightUploadMsg  compressed weight uploads (sharing stage, §2.1)
  ShardUploadMsg   one shard of a miner's weight vector (§5.1 sharded
                   sharing; KeySchema v2)
  ShardReducedMsg  one reducer's reduced copy of a shard (§5.2 redundancy;
                   KeySchema v2)
  AnchorMsg        merged per-stage anchor after butterfly + DiLoCo outer
  ScoreMsg         validator scores feeding the incentive ledger (§3)

KeySchema v3 adds the actor runtime's control plane (miners/validators as
independent processes polling the store; runtime/actor.py):
  LabelsMsg        label batch for one tick (the actor-mode last-stage
                   miner reads labels from the store)
  EpochPlanMsg     the driver's epoch plan: tick schedule + merge census
  TickLossMsg      training watermark — tick loss, published by the
                   last-stage miner when a tick's backward chain starts
  SnapshotMsg      a tracked miner's epoch-start snapshot (validator
                   replay starts here)
  HeartbeatMsg     actor liveness/progress; rides the actor's TCP health
                   endpoint (and optionally the store, under control/hb/)

KeySchema v5 adds the serve plane (inference as a pipeline workload;
docs/SERVE.md):
  ServePlanMsg     the serve session spec (stages, lanes, wire codec)
  ServeRoundPlanMsg  one decode round's lane plan (admission/retire)
  ServeCodeMsg     a stage's boundary output for one (round, lane)
  ServeRequestMsg  a request's prompt envelope
  ServeTokenMsg    one emitted token of a request
  ServeDoneMsg     request completion marker (latency stats payload)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.api.keys import KeySchema


@dataclasses.dataclass(frozen=True)
class ActivationMsg:
    """A boundary activation.  ``stage is None`` marks the pipeline entry
    (the orchestrator's token batch, produced by no miner)."""
    epoch: int
    tick: int
    stage: Optional[int] = None
    miner_uid: Optional[int] = None

    @classmethod
    def tokens(cls, epoch: int, tick: int) -> "ActivationMsg":
        return cls(epoch, tick)

    @property
    def is_tokens(self) -> bool:
        return self.stage is None

    def key(self, schema: KeySchema) -> str:
        if self.is_tokens:
            return schema.tokens(self.epoch, self.tick)
        return schema.activation(self.epoch, self.tick, self.stage,
                                 self.miner_uid)


@dataclasses.dataclass(frozen=True)
class GradientMsg:
    """Gradient w.r.t. the activation miner_uid uploaded at (tick, stage)."""
    epoch: int
    tick: int
    stage: int
    miner_uid: int

    @classmethod
    def for_activation(cls, act: ActivationMsg) -> "GradientMsg":
        assert not act.is_tokens, "no gradient flows into the token batch"
        return cls(act.epoch, act.tick, act.stage, act.miner_uid)

    def key(self, schema: KeySchema) -> str:
        return schema.gradient(self.epoch, self.tick, self.stage,
                               self.miner_uid)


@dataclasses.dataclass(frozen=True)
class WeightUploadMsg:
    """A qualifying miner's compressed weight vector (sharing stage)."""
    epoch: int
    stage: int
    miner_uid: int
    # advisory (payload is already encoded) and not part of the key, so it
    # is excluded from equality — message_for_key must round-trip envelopes
    # regardless of which share codec the config picked
    codec: str = dataclasses.field(default="int8", compare=False)

    def key(self, schema: KeySchema) -> str:
        return schema.weight_upload(self.epoch, self.stage, self.miner_uid)


@dataclasses.dataclass(frozen=True)
class ShardUploadMsg:
    """One contiguous shard of a qualifying miner's flattened weight vector
    (sharded sharing, §5.1).  Shard bounds are plan-determined, not part of
    the key: the butterfly plan is reconstructible from (epoch, stage,
    swarm seed), and the store-side audit only needs shard *identity*."""
    epoch: int
    stage: int
    miner_uid: int
    shard: int
    codec: str = dataclasses.field(default="int8", compare=False)

    def key(self, schema: KeySchema) -> str:
        return schema.shard_upload(self.epoch, self.stage, self.miner_uid,
                                   self.shard)


@dataclasses.dataclass(frozen=True)
class ShardReducedMsg:
    """Reducer ``reducer_uid``'s masked-mean copy of shard ``shard`` —
    each shard gets two of these (the §5.2 redundancy the agreement
    matrix cross-checks).  Reduced copies always ride fp32 (codec
    "none"): they are the consensus artifact the anchor is assembled
    from, and quantizing them a second time would compound codec error."""
    epoch: int
    stage: int
    shard: int
    reducer_uid: int
    codec: str = dataclasses.field(default="none", compare=False)

    def key(self, schema: KeySchema) -> str:
        return schema.shard_reduced(self.epoch, self.stage, self.shard,
                                    self.reducer_uid)


@dataclasses.dataclass(frozen=True)
class AnchorMsg:
    """The merged per-stage anchor every miner downloads at full sync."""
    epoch: int
    stage: int

    def key(self, schema: KeySchema) -> str:
        return schema.anchor(self.epoch, self.stage)


@dataclasses.dataclass(frozen=True)
class ScoreMsg:
    """A validator's epoch verdict on one tracked miner."""
    epoch: int
    validator_uid: int
    miner_uid: int

    def key(self, schema: KeySchema) -> str:
        return schema.score(self.epoch, self.validator_uid, self.miner_uid)


@dataclasses.dataclass(frozen=True)
class LabelsMsg:
    """Label batch for one tick (actor runtime; KeySchema v3).  The
    lockstep driver hands labels to the last miner in-process; actor-mode
    miners and validators read them from the store like everything else."""
    epoch: int
    tick: int

    def key(self, schema: KeySchema) -> str:
        return schema.labels(self.epoch, self.tick)


@dataclasses.dataclass(frozen=True)
class EpochPlanMsg:
    """The event driver's epoch plan (KeySchema v3).  The payload carries
    the full deterministic schedule — tick pathways, batch census, merge
    quorum, qualifying miners, validator assignments — so every actor can
    derive its own work list from one store read."""
    epoch: int

    def key(self, schema: KeySchema) -> str:
        return schema.plan(self.epoch)


@dataclasses.dataclass(frozen=True)
class TickLossMsg:
    """Training watermark (KeySchema v3): the last-stage miner publishes
    tick ``tick``'s loss the moment the backward chain starts — the event
    driver folds these into ``PathwayRecord``s instead of observing the
    loss in-process."""
    epoch: int
    tick: int

    def key(self, schema: KeySchema) -> str:
        return schema.tick_loss(self.epoch, self.tick)


@dataclasses.dataclass(frozen=True)
class SnapshotMsg:
    """A tracked miner's epoch-start snapshot (KeySchema v3): param +
    optimizer leaves and the inner step, published before the miner's
    first tick so its validator can replay the epoch from the same
    state."""
    epoch: int
    miner_uid: int

    def key(self, schema: KeySchema) -> str:
        return schema.snapshot(self.epoch, self.miner_uid)


@dataclasses.dataclass(frozen=True)
class HeartbeatMsg:
    """Actor liveness + progress.  This is the payload of the actor's TCP
    health endpoint (``runtime.actor``); only ``actor`` addresses a store
    key, the rest is status and excluded from equality so a heartbeat
    envelope compares stably across polls."""
    actor: str
    pid: int = dataclasses.field(default=0, compare=False)
    epoch: int = dataclasses.field(default=-1, compare=False)
    items_done: int = dataclasses.field(default=0, compare=False)
    state: str = dataclasses.field(default="idle", compare=False)

    def key(self, schema: KeySchema) -> str:
        return schema.heartbeat(self.actor)


@dataclasses.dataclass(frozen=True)
class ServePlanMsg:
    """The serve session spec (KeySchema v5): published once per session
    so serve actors can derive stage programs, lane caches and every
    later key from one store read."""

    def key(self, schema: KeySchema) -> str:
        return schema.serve_plan()


@dataclasses.dataclass(frozen=True)
class ServeRoundPlanMsg:
    """One decode round's lane plan (KeySchema v5): which request
    occupies each lane and whether its slot is a prefill (admission) or
    a decode step — the driver's continuous-batching decisions, made
    between rounds so stage actors never recompile."""
    round: int

    def key(self, schema: KeySchema) -> str:
        return schema.serve_round_plan(self.round)


@dataclasses.dataclass(frozen=True)
class ServeCodeMsg:
    """Stage ``stage``'s boundary output for ``lane`` in round ``round``
    — a bottleneck wire code mid-chain (optionally the physical int8
    pair), last-token logits on the final stage."""
    round: int
    lane: int
    stage: int

    def key(self, schema: KeySchema) -> str:
        return schema.serve_code(self.round, self.lane, self.stage)


@dataclasses.dataclass(frozen=True)
class ServeRequestMsg:
    """Request ``req``'s prompt envelope (tokens + sampling params ride
    the payload)."""
    req: int

    def key(self, schema: KeySchema) -> str:
        return schema.serve_request(self.req)


@dataclasses.dataclass(frozen=True)
class ServeTokenMsg:
    """Token ``index`` emitted for request ``req`` (index 0 is the first
    sampled continuation of the prompt)."""
    req: int
    index: int

    def key(self, schema: KeySchema) -> str:
        return schema.serve_token(self.req, self.index)


@dataclasses.dataclass(frozen=True)
class ServeDoneMsg:
    """Completion marker for request ``req``; the payload carries the
    per-request latency record."""
    req: int

    def key(self, schema: KeySchema) -> str:
        return schema.serve_done(self.req)


Message = Union[ActivationMsg, GradientMsg, WeightUploadMsg, ShardUploadMsg,
                ShardReducedMsg, AnchorMsg, ScoreMsg, LabelsMsg,
                EpochPlanMsg, TickLossMsg, SnapshotMsg, HeartbeatMsg,
                ServePlanMsg, ServeRoundPlanMsg, ServeCodeMsg,
                ServeRequestMsg, ServeTokenMsg, ServeDoneMsg]

MESSAGE_TYPES = (ActivationMsg, GradientMsg, WeightUploadMsg, ShardUploadMsg,
                 ShardReducedMsg, AnchorMsg, ScoreMsg, LabelsMsg,
                 EpochPlanMsg, TickLossMsg, SnapshotMsg, HeartbeatMsg,
                 ServePlanMsg, ServeRoundPlanMsg, ServeCodeMsg,
                 ServeRequestMsg, ServeTokenMsg, ServeDoneMsg)


def message_for_key(key: str, schema: KeySchema) -> Message:
    """Reconstruct the typed envelope from a raw store key (audit path)."""
    parsed = schema.parse(key)
    f = parsed.fields
    if parsed.kind == "tokens":
        return ActivationMsg(f["epoch"], f["tick"])
    if parsed.kind == "activation":
        return ActivationMsg(f["epoch"], f["tick"], f["stage"], f["uid"])
    if parsed.kind == "gradient":
        return GradientMsg(f["epoch"], f["tick"], f["stage"], f["uid"])
    if parsed.kind == "weights":
        return WeightUploadMsg(f["epoch"], f["stage"], f["uid"])
    if parsed.kind == "shard_upload":
        return ShardUploadMsg(f["epoch"], f["stage"], f["uid"], f["shard"])
    if parsed.kind == "shard_reduced":
        return ShardReducedMsg(f["epoch"], f["stage"], f["shard"],
                               f["reducer"])
    if parsed.kind == "anchor":
        return AnchorMsg(f["epoch"], f["stage"])
    if parsed.kind == "score":
        return ScoreMsg(f["epoch"], f["validator"], f["uid"])
    if parsed.kind == "labels":
        return LabelsMsg(f["epoch"], f["tick"])
    if parsed.kind == "plan":
        return EpochPlanMsg(f["epoch"])
    if parsed.kind == "tick_loss":
        return TickLossMsg(f["epoch"], f["tick"])
    if parsed.kind == "snapshot":
        return SnapshotMsg(f["epoch"], f["uid"])
    if parsed.kind == "heartbeat":
        return HeartbeatMsg(f["actor"])
    if parsed.kind == "serve_plan":
        return ServePlanMsg()
    if parsed.kind == "serve_round_plan":
        return ServeRoundPlanMsg(f["round"])
    if parsed.kind == "serve_code":
        return ServeCodeMsg(f["round"], f["lane"], f["stage"])
    if parsed.kind == "serve_request":
        return ServeRequestMsg(f["req"])
    if parsed.kind == "serve_token":
        return ServeTokenMsg(f["req"], f["index"])
    if parsed.kind == "serve_done":
        return ServeDoneMsg(f["req"])
    raise ValueError(f"unmapped key kind: {parsed.kind}")
