"""Wire format for the socket transport: ``dumps``/``loads`` + framing.

Everything the phases publish must cross a real process boundary
bit-exactly (paper §2: all traffic transits the globally accessible
store).  The payload zoo, concretely:

  * jnp/np arrays of every runtime dtype — fp32 anchors and score
    vectors, int32 token batches, bf16 activations, int8 quantized codes;
  * codec payload dicts from ``core.compression`` (``{"codec", "data",
    "scales", "n", ...}``, plus the gradient wire's ``"shape"`` tuple);
  * plain Python scalars, strings, bytes, lists, tuples and (ordered)
    dicts for request envelopes and store metadata.

Digest contract: ``StateStore`` digests hash each tree leaf's raw bytes
in ``jax.tree_util`` order.  ``loads(dumps(x))`` preserves every array's
dtype, shape and buffer and every container's structure (tuples stay
tuples, dict insertion order is kept), so a payload digested on either
side of the wire yields the *same* digest — the end-to-end tamper
evidence survives serialization.  jax arrays deserialize as numpy arrays
(same bytes; all consumers go through ``jnp.asarray``/numpy anyway).

The encoding is a deliberately boring tagged binary tree (one tag byte
per node, big-endian fixed-width lengths) — no pickle (the store server
must never execute peer-controlled bytecode) and no third-party
dependency.  Frames on the socket are ``u64 length + body``.
"""
from __future__ import annotations

import socket
import struct
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# one tag byte per node
_NONE, _TRUE, _FALSE = b"Z", b"T", b"F"
_INT, _BIGINT, _FLOAT = b"i", b"I", b"f"
_STR, _BYTES = b"s", b"y"
_LIST, _TUPLE, _DICT = b"l", b"t", b"d"
_ARRAY = b"a"

_I64_MIN, _I64_MAX = -(2 ** 63), 2 ** 63 - 1

_U32 = struct.Struct(">I")
_U64 = struct.Struct(">Q")
_I64 = struct.Struct(">q")
_F64 = struct.Struct(">d")


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including the ml_dtypes extension types jax
    uses on the wire (``bfloat16`` activations/codes)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(jnp, name))


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------


def _enc_str(out: bytearray, s: str) -> None:
    raw = s.encode("utf-8")
    out += _U32.pack(len(raw))
    out += raw


def _enc(out: bytearray, obj: Any) -> None:
    if obj is None:
        out += _NONE
    elif isinstance(obj, bool):               # before int: bool is an int
        out += _TRUE if obj else _FALSE
    elif isinstance(obj, int):
        if _I64_MIN <= obj <= _I64_MAX:
            out += _INT
            out += _I64.pack(obj)
        else:
            out += _BIGINT                    # decimal string, length-prefixed
            _enc_str(out, str(obj))
    elif isinstance(obj, float):
        out += _FLOAT
        out += _F64.pack(obj)
    elif isinstance(obj, str):
        out += _STR
        _enc_str(out, obj)
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        raw = bytes(obj)
        out += _BYTES
        out += _U64.pack(len(raw))
        out += raw
    elif isinstance(obj, (np.ndarray, np.generic, jax.Array)):
        # NOT ascontiguousarray: it promotes 0-d arrays to 1-d; tobytes()
        # already yields a C-order copy for any layout
        arr = np.asarray(obj)
        if arr.dtype.hasobject:
            # tobytes() on object arrays would serialize pointers
            raise TypeError(
                f"serde cannot encode object-dtype array: {obj!r}")
        out += _ARRAY
        _enc_str(out, arr.dtype.name)
        out += _U32.pack(arr.ndim)
        for d in arr.shape:
            out += _U64.pack(d)
        raw = arr.tobytes()
        out += _U64.pack(len(raw))
        out += raw
    elif isinstance(obj, (list, tuple)):
        out += _TUPLE if isinstance(obj, tuple) else _LIST
        out += _U32.pack(len(obj))
        for item in obj:
            _enc(out, item)
    elif isinstance(obj, dict):
        out += _DICT
        out += _U32.pack(len(obj))
        for k, v in obj.items():              # insertion order preserved
            _enc(out, k)
            _enc(out, v)
    else:
        raise TypeError(
            f"serde cannot encode {type(obj).__name__!r} "
            f"(supported: None/bool/int/float/str/bytes/list/tuple/dict/"
            f"ndarray): {obj!r}")


def dumps(obj: Any) -> bytes:
    out = bytearray()
    _enc(out, obj)
    return bytes(out)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


class _Reader:
    __slots__ = ("buf", "pos")

    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        end = self.pos + n
        if end > len(self.buf):
            raise ValueError("serde: truncated buffer")
        chunk = self.buf[self.pos:end]
        self.pos = end
        return chunk

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]

    def u64(self) -> int:
        return _U64.unpack(self.take(8))[0]

    def str_(self) -> str:
        return self.take(self.u32()).decode("utf-8")


def _dec(r: _Reader) -> Any:
    tag = r.take(1)
    if tag == _NONE:
        return None
    if tag == _TRUE:
        return True
    if tag == _FALSE:
        return False
    if tag == _INT:
        return _I64.unpack(r.take(8))[0]
    if tag == _BIGINT:
        return int(r.str_())
    if tag == _FLOAT:
        return _F64.unpack(r.take(8))[0]
    if tag == _STR:
        return r.str_()
    if tag == _BYTES:
        return r.take(r.u64())
    if tag == _ARRAY:
        dtype = _np_dtype(r.str_())
        shape = tuple(r.u64() for _ in range(r.u32()))
        raw = r.take(r.u64())
        # copy: detaches from the request buffer and yields a writable array
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
    if tag in (_LIST, _TUPLE):
        items = [_dec(r) for _ in range(r.u32())]
        return tuple(items) if tag == _TUPLE else items
    if tag == _DICT:
        return {_dec(r): _dec(r) for _ in range(r.u32())}
    raise ValueError(f"serde: unknown tag {tag!r} at offset {r.pos - 1}")


def loads(buf: bytes) -> Any:
    r = _Reader(buf)
    obj = _dec(r)
    if r.pos != len(buf):
        raise ValueError(
            f"serde: {len(buf) - r.pos} trailing bytes after decode")
    return obj


# ---------------------------------------------------------------------------
# socket framing: u64 big-endian length + body
# ---------------------------------------------------------------------------


def send_frame(sock: socket.socket, body: bytes) -> int:
    """Write one length-prefixed frame; returns bytes put on the wire."""
    header = _U64.pack(len(body))
    if len(body) < (1 << 16):
        sock.sendall(header + body)   # one packet for small frames
    else:
        sock.sendall(header)          # no full copy of large payloads
        sock.sendall(body)
    return len(body) + 8


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes, or None on a clean EOF at a frame edge."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise ConnectionError(
                f"peer closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> Optional[bytes]:
    """Read one frame; None when the peer closed the connection."""
    header = _recv_exact(sock, 8)
    if header is None:
        return None
    return _recv_exact(sock, _U64.unpack(header)[0])


# ---------------------------------------------------------------------------
# message registry: typed envelopes on the wire
# ---------------------------------------------------------------------------
#
# Every ``*Msg`` dataclass in ``api/messages.py`` is registered here,
# *explicitly* — no ``__subclasses__`` discovery — so coverage is visible
# to a reader, to the swarmlint ``serde-coverage`` rule (which cross-checks
# this block against messages.py by AST), and to the registry-driven
# round-trip test in tests/test_serde.py.  A new message type that skips
# this block fails the lint and the test before it can fail on a socket.

import dataclasses as _dataclasses

from repro.api import messages as _messages

_MESSAGE_TYPES: dict = {}


def _register(cls: type) -> type:
    _MESSAGE_TYPES[cls.__name__] = cls
    return cls


_register(_messages.ActivationMsg)
_register(_messages.GradientMsg)
_register(_messages.WeightUploadMsg)
_register(_messages.ShardUploadMsg)
_register(_messages.ShardReducedMsg)
_register(_messages.AnchorMsg)
_register(_messages.ScoreMsg)
# KeySchema v3: the actor runtime's control plane (labels, epoch plan,
# loss watermarks, snapshots) + the health-endpoint heartbeat envelope
_register(_messages.LabelsMsg)
_register(_messages.EpochPlanMsg)
_register(_messages.TickLossMsg)
_register(_messages.SnapshotMsg)
_register(_messages.HeartbeatMsg)
# KeySchema v5: the serve plane (session/round plans, boundary codes,
# request envelopes, emitted tokens, completion markers — docs/SERVE.md)
_register(_messages.ServePlanMsg)
_register(_messages.ServeRoundPlanMsg)
_register(_messages.ServeCodeMsg)
_register(_messages.ServeRequestMsg)
_register(_messages.ServeTokenMsg)
_register(_messages.ServeDoneMsg)


def registered_message_names() -> tuple:
    """Registered type names, sorted — drives the parametrized round-trip
    test so test coverage tracks the registry automatically."""
    return tuple(sorted(_MESSAGE_TYPES))


def message_type(name: str) -> type:
    return _MESSAGE_TYPES[name]


def encode_message(msg: Any) -> bytes:
    """Serialize a registered message dataclass as a tagged envelope."""
    cls = type(msg)
    if _MESSAGE_TYPES.get(cls.__name__) is not cls:
        raise TypeError(
            f"{cls.__name__} is not a registered wire message; add a "
            f"_register(...) entry in api/serde.py")
    fields = {f.name: getattr(msg, f.name)
              for f in _dataclasses.fields(msg)}
    return dumps({"__msg__": cls.__name__, "fields": fields})


def decode_message(data: bytes) -> Any:
    """Inverse of :func:`encode_message`; rejects unknown types."""
    obj = loads(data)
    if not (isinstance(obj, dict) and "__msg__" in obj):
        raise ValueError("not a message envelope")
    name = obj["__msg__"]
    cls = _MESSAGE_TYPES.get(name)
    if cls is None:
        raise ValueError(f"unknown message type {name!r}")
    return cls(**obj["fields"])
