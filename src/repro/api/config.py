"""Swarm-level configuration + per-epoch stats (shared by phases/driver).

``SwarmConfig``/``EpochStats`` moved here from ``repro.runtime.orchestrator``
(which re-exports them unchanged) so the api package never imports the
legacy facade module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import clasp


@dataclasses.dataclass(frozen=True)
class SwarmConfig:
    n_stages: int = 3
    miners_per_stage: int = 3
    inner_steps: int = 8              # ticks per epoch (training stage)
    b_min: int = 4                    # BATCHES_BEFORE_MERGING
    quorum_frac: float = 0.5
    batch_size: int = 4
    seq_len: int = 32
    compress: bool = True
    bottleneck_dim: int = 16
    share_codec: str = "int8"         # compressed-sharing stage codec
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    gamma_hours: float = 10.0         # score decay
    sync_interval_hours: float = 0.5  # T_s
    validators: int = 1
    validate_max_items: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass
class EpochStats:
    epoch: int
    mean_loss: float
    b_eff: int
    batches: dict[int, int]
    merged_stages: int
    stalled_ticks: int
    agreement: dict[int, np.ndarray]      # stage -> (n,n) agreement matrix
    clasp: Optional[clasp.ClaspReport]
    validation: list
    emissions: dict[int, float]
