"""Swarm-level configuration + per-epoch stats (shared by phases/driver).

``SwarmConfig``/``EpochStats`` moved here from ``repro.runtime.orchestrator``
(which re-exports them unchanged) so the api package never imports the
legacy facade module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import clasp


@dataclasses.dataclass(frozen=True)
class SwarmConfig:
    n_stages: int = 3
    miners_per_stage: int = 3
    inner_steps: int = 8              # ticks per epoch (training stage)
    b_min: int = 4                    # BATCHES_BEFORE_MERGING
    quorum_frac: float = 0.5
    batch_size: int = 4
    seq_len: int = 32
    compress: bool = True
    bottleneck_dim: int = 16
    share_codec: str = "int8"         # compressed-sharing stage codec
    # weight-exchange path for sharing+sync: "dense" is the seed-exact
    # golden oracle (full vectors through the store, butterfly reduced
    # centrally in-process); "sharded" runs the reduce as per-miner
    # store-and-forward shard exchanges over the transport (§5.1-5.3,
    # KeySchema v2) — same merged anchors, honest per-link bytes
    sync_mode: str = "dense"
    # backward-wire codec for TrainingPhase gradient hand-offs: "none" keeps
    # the seed trajectory bit-exact; "int8" ships blockwise-int8 gradient
    # codes through the store (paper's symmetric compression — a *different*
    # scenario, the dequantized codes are what miners train on)
    wire_codec: str = "none"
    # on-mesh pipeline-engine knobs, surfaced so scenarios/benches mint
    # their PipelineSpec from the swarm config (see pipeline_spec()).
    # pipeline_schedule must name a compiled schedule from
    # repro.core.pipeline.SCHEDULES; pipeline_virtual_stages > 1 splits
    # each device's model slice into V chunks (interleaved only)
    pipeline_schedule: str = "gpipe"
    pipeline_virtual_stages: int = 1
    pipeline_microbatches: int = 8
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    gamma_hours: float = 10.0         # score decay
    sync_interval_hours: float = 0.5  # T_s
    validators: int = 1
    validate_max_items: Optional[int] = None
    # store hygiene: keep only the last ``retain_epochs`` epochs of the
    # weights/ and scores/ planes (activations are always GC'd at epoch
    # end).  None keeps everything — the default, because replay/audit
    # tooling reads historical epochs; long-running swarms should set a
    # window or the store grows without bound
    retain_epochs: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        # a typo'd codec would silently fall through to the uncompressed
        # gradient wire (TrainingPhase gates on the exact string) — fail loud
        assert self.wire_codec in ("none", "int8"), self.wire_codec
        # schedule names come from the compiler registry, not a literal
        # tuple kept in sync by hand (swarmlint enforces the same rule on
        # call sites); imported lazily so merely importing this module
        # stays jax-free
        from repro.core.pipeline import SCHEDULES
        assert self.pipeline_schedule in SCHEDULES, self.pipeline_schedule
        assert self.pipeline_virtual_stages >= 1, \
            self.pipeline_virtual_stages
        assert self.sync_mode in ("dense", "sharded"), self.sync_mode
        assert self.retain_epochs is None or self.retain_epochs >= 1, \
            f"retain_epochs must be None or >= 1: {self.retain_epochs}"
        # sharded sync needs a codec whose encode commutes with
        # block-aligned slicing (topk is global over the vector) — fail at
        # construction, not mid-epoch in SharingPhase
        if self.sync_mode == "sharded":
            from repro.core.compression import SLICEABLE_CODECS
            assert self.share_codec in SLICEABLE_CODECS, \
                (f"share_codec {self.share_codec!r} cannot shard "
                 f"losslessly under sync_mode='sharded'")

    def pipeline_spec(self):
        """Mint the on-mesh ``PipelineSpec`` these knobs describe (schedule,
        wire codec, bottleneck) — the bridge between the swarm-level config
        and ``repro.core.pipeline``'s shard_map engine."""
        from repro.core.pipeline import PipelineSpec
        return PipelineSpec(
            n_stages=self.n_stages,
            n_microbatches=self.pipeline_microbatches,
            compress=self.compress,
            bottleneck_dim=self.bottleneck_dim,
            schedule=self.pipeline_schedule,
            wire_codec=self.wire_codec,
            virtual_stages=self.pipeline_virtual_stages,
        )


@dataclasses.dataclass
class EpochStats:
    epoch: int
    mean_loss: float
    b_eff: int
    batches: dict[int, int]
    merged_stages: int
    stalled_ticks: int
    agreement: dict[int, np.ndarray]      # stage -> (n,n) agreement matrix
    clasp: Optional[clasp.ClaspReport]
    validation: list
    emissions: dict[int, float]
    # store-side reduce audits (sharded sync only; ReduceAuditPhase)
    reduce_audits: list = dataclasses.field(default_factory=list)
    # ticks re-planned onto survivors after an actor death (EventDriver
    # graceful degradation; always 0 on the lockstep timeline)
    replanned_ticks: int = 0
