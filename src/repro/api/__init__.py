"""Peer-protocol API for the swarm runtime (see docs/API.md).

Typed messages + versioned key schema + pluggable transports + phase-based
epoch driver.  ``Swarm.create(...)`` is the entry point; the legacy
``repro.runtime.Orchestrator`` is a thin subclass kept for compatibility.
"""
from repro.api.config import EpochStats, SwarmConfig  # noqa: F401
from repro.api.keys import KeySchema, SCHEMA_VERSION  # noqa: F401
from repro.api.messages import (  # noqa: F401
    ActivationMsg,
    AnchorMsg,
    EpochPlanMsg,
    GradientMsg,
    HeartbeatMsg,
    LabelsMsg,
    Message,
    MESSAGE_TYPES,
    ScoreMsg,
    ShardReducedMsg,
    ShardUploadMsg,
    SnapshotMsg,
    TickLossMsg,
    WeightUploadMsg,
    message_for_key,
)
from repro.api.phases import (  # noqa: F401
    EpochDriver,
    EpochState,
    EventDriver,
    OverlappedTrainingSharing,
    Phase,
    ReduceAuditPhase,
    SharingPhase,
    SyncPhase,
    TrainingPhase,
    ValidationPhase,
    default_phases,
    overlapped_phases,
    sharded_phases,
)
from repro.api.swarm import Swarm  # noqa: F401
from repro.api.transport import (  # noqa: F401
    InProcessTransport,
    LinkSpec,
    NetworkModel,
    SimulatedNetworkTransport,
    SocketTransport,
    StoreKeyError,
    Transport,
)
