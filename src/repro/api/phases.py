"""Phase objects behind the ``Phase`` protocol + the ``EpochDriver``.

The seed ``Orchestrator.run_epoch`` hard-coded the Fig 2 epoch timeline in
one ~180-line method; each stage is now its own object so scenarios can
re-order, replace or extend the timeline (async joins, multi-validator
panels, partition faults) without touching the core loop:

  TrainingPhase    CLASP-sampled pathways, forward/backward over the
                   transport, SWARM rerouting, stragglers
  ValidationPhase  validators replay tracked miners from their sync
                   snapshots (runs *before* merge: replay starts from the
                   pre-merge snapshot, exactly as the seed did)
  SharingPhase     qualifying miners upload codec-compressed weights —
                   dense full vectors, or per-shard payloads when
                   ``SwarmConfig.sync_mode == "sharded"`` (§5.1)
  SyncPhase        butterfly all-reduce + DiLoCo outer step + anchor
                   download for everyone (incl. joiners).  Dense mode
                   reduces centrally in-process (the golden oracle);
                   sharded mode runs the reduce as per-miner
                   store-and-forward actions over the transport
                   (``ButterflyExecutor``), so per-link byte accounting
                   reproduces the §5.3 closed form 4W + 2W/N
  ReduceAuditPhase sharded only: validators rebuild the agreement matrix
                   from the store's redundant reduced copies (trustless
                   tamper detection from wire artifacts alone)

Determinism contract: with ``InProcessTransport`` the default timeline
reproduces the seed trajectory bit-exactly — every RNG draw (pathway
sampling, drop rolls, fault corruption) happens in the same order as the
seed monolith.  Phases that reorder RNG-consuming work define a *different*
scenario, not a bug, but must say so.

``EventDriver`` (the actor runtime, ROADMAP item 1) replaces the lockstep
phase barriers with store-observed completion events: it publishes the
epoch *plan* and the per-tick token/label batches up front, then advances
on watermark keys (tick losses, validator scores, shard/weight uploads)
that concurrently running actor processes publish as they finish.  All
swarm RNG draws happen at plan time in exactly the lockstep order, so the
loss trajectory reproduces the in-process oracle at the same seed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable, Optional, Protocol, runtime_checkable

import jax
from jax.flatten_util import ravel_pytree
import jax.numpy as jnp
import numpy as np

from repro.api.config import EpochStats
from repro.api.messages import (
    ActivationMsg,
    AnchorMsg,
    EpochPlanMsg,
    GradientMsg,
    LabelsMsg,
    ScoreMsg,
    ServeCodeMsg,
    ServeDoneMsg,
    ServePlanMsg,
    ServeRequestMsg,
    ServeRoundPlanMsg,
    ServeTokenMsg,
    TickLossMsg,
    WeightUploadMsg,
)
from repro.core import butterfly, clasp, compression, diloco


@dataclasses.dataclass
class EpochState:
    """Mutable scratchpad one epoch's phases write into; the driver folds
    it into ``EpochStats`` at the end."""
    epoch: int
    snapshots: dict[int, dict]
    records: list = dataclasses.field(default_factory=list)
    labels_for: dict = dataclasses.field(default_factory=dict)
    stalled: int = 0
    validation: list = dataclasses.field(default_factory=list)
    batches: dict[int, int] = dataclasses.field(default_factory=dict)
    merge_quorum: bool = False
    b_eff: int = 0
    # sharing -> sync handoff: stage -> (qualifying miners, decoded uploads)
    qualified: dict[int, list] = dataclasses.field(default_factory=dict)
    uploads: dict[int, dict[int, np.ndarray]] = dataclasses.field(
        default_factory=dict)
    # sharded-sync handoff: stage -> store-and-forward executor (the plan
    # rides on it); dense runs leave this empty
    executors: dict[int, Any] = dataclasses.field(default_factory=dict)
    merged_stages: int = 0
    agreement: dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    reduce_audits: list = dataclasses.field(default_factory=list)
    # graceful degradation (EventDriver): ticks re-assigned to survivors
    # after an ActorDied, folded into EpochStats.replanned
    replanned: int = 0


@runtime_checkable
class Phase(Protocol):
    """One slice of the epoch timeline.  ``run`` mutates ``state`` (and the
    swarm: miner params, anchors, ledger) through the swarm's transport."""
    name: str

    def run(self, swarm: Any, state: EpochState) -> None: ...


class TrainingPhase:
    name = "training"

    def run(self, swarm, state: EpochState) -> None:
        S = swarm.config
        if getattr(S, "pipeline_virtual_stages", 1) != 1:
            # one miner owns one contiguous stage slice; an interleaved
            # timetable would need each miner to hold V disjoint chunks
            # and the store schema to key activations by chunk, not stage
            raise NotImplementedError(
                "store-path training is stage-granular: "
                "pipeline_virtual_stages > 1 only applies to the on-mesh "
                "engine (repro.core.pipeline / launch.train)")
        tp, schema = swarm.transport, swarm.transport.schema
        for tick in range(S.inner_steps):
            batch = swarm.corpus.batch(swarm.global_tick)
            swarm.global_tick += 1
            # SWARM routing: sample one available miner per stage, reroute
            pathway = []
            ok = True
            for s in range(S.n_stages):
                avail = [m for m in swarm.stage_miners(s)
                         if swarm.available(m, tick)]
                if not avail:
                    ok = False
                    break
                pathway.append(avail[swarm.rng.randint(len(avail))])
            if not ok:
                state.stalled += 1     # a whole layer offline: pipeline stall
                continue

            tok_msg = ActivationMsg.tokens(state.epoch, tick)
            tp.publish(tok_msg, jnp.asarray(batch["tokens"]),
                       actor="orchestrator")
            # ---------------- forward chain ----------------
            in_key = tok_msg.key(schema)
            last_in_key = in_key
            for s, miner in enumerate(pathway):
                out_msg = ActivationMsg(state.epoch, tick, s, miner.uid)
                out_key = out_msg.key(schema)
                if s == S.n_stages - 1:
                    last_in_key = in_key
                out = miner.forward(tick, in_key, out_key)
                # an adversarial miner uploads a corrupted activation in
                # place of its honest output — validators catch the mismatch
                # on replay, CLASP catches the downstream loss inflation
                b = swarm.faults.behavior(miner.uid)
                if s < S.n_stages - 1 and (b.free_ride
                                           or b.tamper_activations > 0):
                    corrupted = swarm.faults.corrupt_activation(
                        miner.uid, np.asarray(out, np.float32))
                    tp.publish(out_msg,
                               jnp.asarray(corrupted).astype(out.dtype),
                               actor=miner.actor)
                in_key = out_key
            last = pathway[-1]
            labels = jnp.asarray(batch["labels"])
            state.labels_for[last_in_key] = labels

            # ---------------- backward chain ----------------
            loss, g = last.backward_last(last_in_key, labels)
            state.records.append(clasp.PathwayRecord(
                tuple(m.uid for m in pathway), loss))
            for s in range(S.n_stages - 2, -1, -1):
                miner = pathway[s]
                msg = GradientMsg(state.epoch, tick, s, miner.uid)
                if S.wire_codec == "int8":
                    # the paper's symmetric compression: gradient hand-offs
                    # ship as blockwise-int8 codes (store bytes and the
                    # simulated clock see the real on-wire size); miners
                    # train on the dequantized codes, and validator replay
                    # decodes the same payload, so both sides see one wire
                    flat = jnp.ravel(jnp.asarray(g, jnp.float32))
                    payload = dict(compression.encode(flat, "int8"),
                                   shape=tuple(np.shape(g)))
                    tp.publish(msg, payload, actor="orchestrator")
                    g = jnp.reshape(compression.decode(payload),
                                    np.shape(g)).astype(jnp.asarray(g).dtype)
                else:
                    tp.publish(msg, g, actor="orchestrator")
                g = miner.backward(miner.work_log[-1].sample_key, g)


class ValidationPhase:
    """Each validator tracks a random miner (§3: random assignment) and
    publishes its verdict as a ``ScoreMsg`` so emissions are auditable
    from the store alone.  Only snapshotted miners are assignable: an
    async joiner registered mid-epoch has nothing to replay yet and is
    tracked from its first full epoch."""
    name = "validation"

    def run(self, swarm, state: EpochState) -> None:
        t_now = state.epoch * swarm.config.sync_interval_hours
        # a miner registered mid-epoch (async join, §2.2) has no epoch-start
        # snapshot to replay from: it is skipped this epoch and becomes
        # trackable from the next, after its first full sync
        uids = sorted(u for u in swarm.miners if u in state.snapshots)
        if not uids:
            return
        for v in swarm.validators:
            uid = uids[swarm.rng.randint(len(uids))]
            m = swarm.miners[uid]
            res = v.validate_epoch(m, state.snapshots[uid], state.epoch,
                                   t_now, state.labels_for,
                                   max_items=swarm.config.validate_max_items)
            swarm.transport.publish(
                ScoreMsg(state.epoch, v.uid, uid),
                np.asarray([res.score, res.checked, res.passed,
                            res.min_cosine], np.float32),
                actor=v.actor)
            state.validation.append(res)


class SharingPhase:
    """Compressed sharing (§2.1): qualifying miners (B_m >= B_min, quorum)
    upload codec-compressed weight vectors within their layer.

    ``sync_mode="sharded"`` uploads per-shard payloads on the butterfly
    plan's (block-aligned) bounds instead of one dense vector — same bytes
    on the wire, but addressable at shard granularity so the reduce can be
    store-and-forward.  RNG order matches the dense branch (weights read,
    then fault corruption, in qualifying order), so fault-free trajectories
    are unchanged."""
    name = "sharing"

    def run(self, swarm, state: EpochState) -> None:
        S = swarm.config
        state.batches = {m.uid: m.batches_done
                         for m in swarm.miners.values()}
        state.b_eff = diloco.effective_batch(state.batches, S.b_min)
        state.merge_quorum = diloco.should_merge(state.batches, S.b_min,
                                                 S.quorum_frac)
        if not state.merge_quorum:
            return
        for s in range(S.n_stages):
            qual = [m for m in swarm.stage_miners(s)
                    if m.batches_done >= S.b_min]
            if len(qual) < 2:
                continue
            if S.sync_mode == "sharded":
                self._share_sharded(swarm, state, s, qual)
            else:
                self._share_dense(swarm, state, s, qual)

    def _share_dense(self, swarm, state: EpochState, s: int,
                     qual: list) -> None:
        S = swarm.config
        uploads: dict[int, np.ndarray] = {}
        with swarm.transport.parallel():   # distinct links: overlap
            for idx, m in enumerate(qual):
                vec = m.weights_vector()
                vec = swarm.faults.corrupt_weights(m.uid, vec)
                payload = compression.encode(jnp.asarray(vec),
                                             S.share_codec)
                swarm.transport.publish(
                    WeightUploadMsg(state.epoch, s, m.uid,
                                    codec=S.share_codec),
                    payload, actor=m.actor)
                uploads[idx] = np.asarray(
                    compression.decode(payload, vec.shape[0]))
        state.qualified[s] = qual
        state.uploads[s] = uploads

    def _share_sharded(self, swarm, state: EpochState, s: int,
                       qual: list) -> None:
        S = swarm.config
        assert S.share_codec in compression.SLICEABLE_CODECS, \
            f"share_codec {S.share_codec!r} cannot shard losslessly"
        vec0 = qual[0].weights_vector()
        align = compression.INT8_BLOCK if S.share_codec == "int8" else 1
        plan = butterfly.make_plan(len(qual), int(vec0.shape[0]),
                                   seed=S.seed + state.epoch * 131 + s,
                                   align=align)
        ex = butterfly.ButterflyExecutor(
            plan, swarm.transport, epoch=state.epoch, stage=s,
            uids=[m.uid for m in qual], codec=S.share_codec)
        with swarm.transport.parallel():   # distinct links: overlap
            for idx, m in enumerate(qual):
                vec = vec0 if idx == 0 else m.weights_vector()
                vec = swarm.faults.corrupt_weights(m.uid, vec)
                ex.upload_vector(idx, vec, actor=m.actor)
        state.qualified[s] = qual
        state.executors[s] = ex


class SyncPhase:
    """Butterfly all-reduce per layer (agreement matrix exposes tamperers),
    DiLoCo outer Nesterov step on the per-stage anchor, then everyone —
    stragglers and joiners included — downloads the anchor.

    Dense mode reduces the decoded uploads centrally in-process (the
    golden oracle).  Sharded mode executes the same reduce as per-miner
    store-and-forward actions: each qualifying miner downloads all N
    copies of its assigned shards, masked-merges them and re-uploads its
    reduced copy — then the anchor is assembled from the redundant copies
    in the store.  Anchors match the dense oracle to float equality
    (block-aligned shard codes), and per-miner link bytes reproduce the
    §5.3 closed form 4W + 2W/N."""
    name = "sync"

    def run(self, swarm, state: EpochState) -> None:
        if not state.merge_quorum:
            return
        for s, qual in state.qualified.items():
            if s in state.executors:
                merged = self._reduce_sharded(swarm, state, s, qual)
            else:
                merged = self._reduce_dense(swarm, state, s, qual)
            self._outer_step_and_full_sync(swarm, state, s, merged)

    def _reduce_dense(self, swarm, state: EpochState, s: int,
                      qual: list) -> np.ndarray:
        S = swarm.config
        uploads = state.uploads[s]
        plan = butterfly.make_plan(len(qual), uploads[0].shape[0],
                                   seed=S.seed + state.epoch * 131 + s)
        # a weight-tampering miner also reduces dishonestly: its merged
        # shard copies deviate, which is what the agreement matrix
        # exposes (paper Fig 7a)
        tamper = {idx: swarm.faults.behavior(m.uid).tamper_weights
                  for idx, m in enumerate(qual)
                  if swarm.faults.behavior(m.uid).tamper_weights > 0}
        copies = butterfly.reduce_with_copies(plan, uploads,
                                              tamper=tamper or None)
        state.agreement[s] = butterfly.agreement_matrix(plan, copies)
        merged, _, _ = butterfly.reduce_shards(plan, uploads)
        return merged

    def _reduce_sharded(self, swarm, state: EpochState, s: int,
                        qual: list) -> np.ndarray:
        ex = state.executors[s]
        # every reducer's download->merge->re-upload rides its own link;
        # distinct links overlap on the simulated clock
        with swarm.transport.parallel():
            for idx, m in enumerate(qual):
                tamper = swarm.faults.behavior(m.uid).tamper_weights
                m.run_reduce(ex, idx, tamper=tamper if tamper > 0 else 0.0)
        merged, _, _ = ex.collect(actor="orchestrator")
        state.agreement[s] = ex.last_agreement   # computed inside collect
        return merged

    def _outer_step_and_full_sync(self, swarm, state: EpochState, s: int,
                                  merged: np.ndarray) -> None:
        S = swarm.config
        # --- DiLoCo outer step on the per-stage anchor ---
        _, unravel = ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32),
                         swarm.anchors[s]))
        avg = unravel(jnp.asarray(merged))
        swarm.outer[s] = diloco.outer_update(
            swarm.outer[s], avg, outer_lr=S.outer_lr,
            outer_momentum=S.outer_momentum)
        swarm.anchors[s] = jax.tree.map(
            lambda a, p: a.astype(p.dtype), swarm.outer[s].anchor,
            swarm.anchors[s])
        # --- full sync: every miner (incl. stragglers/joiners) downloads
        anchor_vec, _ = ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32),
                         swarm.anchors[s]))
        msg = AnchorMsg(state.epoch, s)
        swarm.transport.publish(msg, np.asarray(anchor_vec),
                                actor="orchestrator")
        with swarm.transport.parallel():
            for m in swarm.stage_miners(s):
                vec = swarm.transport.fetch(msg, actor=m.actor)
                m.load_weights_vector(vec)
        state.merged_stages += 1


class ReduceAuditPhase:
    """Sharded-sync audit (runs after the merge): each validator rebuilds
    the shard agreement matrix from the store's redundant reduced copies —
    tampering reducers are flagged from wire artifacts alone, no miner
    state or plan reconstruction needed (§5.2, Fig 7a)."""
    name = "reduce_audit"

    def run(self, swarm, state: EpochState) -> None:
        for s in sorted(state.executors):
            for v in swarm.validators:
                state.reduce_audits.append(
                    v.audit_reduce(state.epoch, s))


class OverlappedTrainingSharing:  # swarmlint: implements=Phase
    """Async-phases scenario (ROADMAP open item): qualifying miners upload
    their compressed weights *while* training-tick activations still stream,
    inside one ``transport.parallel()`` block.

    Clock-model honesty: ``parallel()`` overlaps transfers across *links*
    only — a miner's own weight upload still serializes with its own
    activation hand-offs on its link, so what the scenario hides is
    idle-link time (uploads ride links whose miners are waiting for their
    next tick).  Within the block the causally-sequential cross-link
    activation chain is also overlapped, so the saved seconds reported by
    bench_swarm are an upper bound on the true overlap win.  RNG order equals
    the default timeline's (sharing draws no swarm RNG), so the trajectory
    is unchanged for fault-free swarms — bench_swarm asserts equal loss.
    """
    name = "training+sharing"

    def __init__(self):
        self.training = TrainingPhase()
        self.sharing = SharingPhase()

    def run(self, swarm, state: EpochState) -> None:
        with swarm.transport.parallel():
            self.training.run(swarm, state)
            self.sharing.run(swarm, state)


def default_phases() -> list[Phase]:
    """Seed-equivalent timeline.  Validation precedes merge because replay
    starts from the epoch-start snapshot (the miner's last full sync)."""
    return [TrainingPhase(), ValidationPhase(), SharingPhase(), SyncPhase()]


def overlapped_phases() -> list[Phase]:
    """Async scenario: training + sharing overlap on the simulated clock;
    validation still precedes the merge (SyncPhase applies the uploads)."""
    return [OverlappedTrainingSharing(), ValidationPhase(), SyncPhase()]


def sharded_phases() -> list[Phase]:
    """Store-and-forward timeline (``sync_mode="sharded"``): the default
    timeline plus the post-merge store-side reduce audit.  Sharing/Sync
    branch on the config, so the phase objects themselves are the same."""
    return [TrainingPhase(), ValidationPhase(), SharingPhase(), SyncPhase(),
            ReduceAuditPhase()]


def revise_plan(plan: dict, done_ticks: set, dead_uid: int,
                survivor: Optional[int], gradient_missing) -> tuple:
    """Pure re-planning after a miner death — the graceful-degradation
    core, kept free of transports/processes so it unit-tests in isolation.

    For every tick the dead miner participates in:

      * loss already published (``done_ticks``) — the tick stands as
        trained; if the dead miner's *backward* hand-off never landed
        (``gradient_missing``), the tick is **orphaned**: miners blocked
        on its broken gradient chain abandon that backward;
      * loss pending — the dead slot is substituted with ``survivor``
        (the survivor redoes the stage forward from the still-stored
        upstream activation), or the tick is **dropped** when the stage
        has no survivor (counts as stalled, like an all-offline layer).

    ``qualified`` is **fixed at plan time** — a revision never rewrites
    the merge layout, because actors may already be mid-reduce against
    it (different actors folding different layouts would shard against
    different butterfly plans).  The driver masks dead participants at
    reduce time instead: dense averages the uploads that arrived,
    sharded fails over to the surviving redundant copy.  ``tracked`` is
    kept — the validator publishes a partial score over what it already
    checked (the ``dead`` list tells it to stop).  Returns
    ``(revision, n_replanned, orphaned, dropped)``.
    """
    stage = plan["stage_of"][dead_uid]
    ticks: list = []
    orphaned: list = []
    dropped: list = []
    n_replanned = 0
    for t, uids in plan["ticks"]:
        uids = tuple(uids)
        if uids[stage] != dead_uid:
            ticks.append((t, uids))
            continue
        if t in done_ticks:
            ticks.append((t, uids))
            if stage > 0 and gradient_missing(t, uids):
                orphaned.append(t)
            continue
        if survivor is None:
            dropped.append(t)
            continue
        ticks.append((t, uids[:stage] + (survivor,) + uids[stage + 1:]))
        n_replanned += 1
    revision = dict(
        plan,
        ticks=tuple(ticks),
        orphaned=tuple(sorted(set(plan.get("orphaned", ())) | set(orphaned))),
        dropped=tuple(sorted(set(plan.get("dropped", ())) | set(dropped))),
        dead=tuple(sorted(set(plan.get("dead", ())) | {dead_uid})),
    )
    return revision, n_replanned, orphaned, dropped


class EpochDriver:
    """Runs the phase list over a swarm and folds the scratchpad into
    ``EpochStats``.  Swap/extend ``phases`` to define new scenarios."""

    def __init__(self, phases: Optional[Iterable[Phase]] = None):
        self.phases: list[Phase] = list(phases or default_phases())
        self._gc_floor = 0          # first epoch whose weights/scores remain
        # retention pins (docs/CHAOS.md): tag -> epoch.  GC never advances
        # past the lowest pin, so the weight/score/control keys a
        # crash-resume replay still needs survive even when
        # ``retain_epochs`` is smaller than the resume distance
        self._pins: dict[str, int] = {}

    def pin_retention(self, tag: str, epoch: int) -> None:
        """Hold every GC floor at or below ``epoch`` until released —
        called with a respawning actor's snapshot epoch so its forward
        replay finds the anchors/plans it needs."""
        self._pins[tag] = min(int(epoch), self._pins.get(tag, int(epoch)))

    def release_retention(self, tag: str) -> None:
        self._pins.pop(tag, None)

    def _pin_floor(self) -> Optional[int]:
        return min(self._pins.values()) if self._pins else None

    def run_epoch(self, swarm) -> EpochStats:
        for m in swarm.miners.values():
            m.reset_epoch()
        state = EpochState(
            epoch=swarm.epoch,
            snapshots={uid: m.snapshot()
                       for uid, m in swarm.miners.items()})
        for phase in self.phases:
            phase.run(swarm, state)
        return self._finalize(swarm, state)

    def _finalize(self, swarm, state: EpochState) -> EpochStats:
        """Fold the epoch scratchpad into ``EpochStats`` and GC the store —
        shared by the lockstep and event-driven timelines."""
        if not state.batches:
            # a timeline without SharingPhase still reports the batch census
            state.batches = {m.uid: m.batches_done
                             for m in swarm.miners.values()}
            state.b_eff = diloco.effective_batch(state.batches,
                                                 swarm.config.b_min)

        n_miners = len(swarm.miners)
        layer_of = np.array([swarm.miners[u].stage
                             for u in sorted(swarm.miners.keys())])
        report = (clasp.attribute(state.records, n_miners, layer_of)
                  if state.records else None)
        t_now = swarm.epoch * swarm.config.sync_interval_hours
        swarm.ledger.prune(t_now)
        emissions = swarm.ledger.emissions(
            t_now, miners=sorted(swarm.miners.keys()))

        stats = EpochStats(
            epoch=swarm.epoch,
            mean_loss=float(np.mean([r.loss for r in state.records]))
            if state.records else float("nan"),
            b_eff=state.b_eff,
            batches=dict(state.batches),
            merged_stages=state.merged_stages,
            stalled_ticks=state.stalled,
            agreement=state.agreement,
            clasp=report,
            validation=state.validation,
            emissions=emissions,
            reduce_audits=state.reduce_audits,
            replanned_ticks=state.replanned,
        )
        swarm.history.append(stats)
        swarm.epoch += 1
        # activations from this epoch are garbage-collected from the store
        schema = swarm.transport.schema
        swarm.transport.delete_prefix(
            schema.activations_prefix(stats.epoch))
        # weight/score planes: retention-window GC.  The seed behaviour
        # (keep everything, for replay/audit) is retain_epochs=None; with a
        # window of K, only the last K epochs' weights/ and scores/ survive
        # — long runs no longer grow the store without bound
        retain = swarm.config.retain_epochs
        if retain is not None:
            pin = self._pin_floor()
            while self._gc_floor <= stats.epoch - retain \
                    and (pin is None or self._gc_floor < pin):
                e = self._gc_floor
                swarm.transport.delete_prefix(schema.weights_prefix(e))
                swarm.transport.delete_prefix(schema.scores_prefix(e))
                self._gc_floor += 1
        return stats


class EventDriver(EpochDriver):
    """Event-driven epoch timeline for the concurrent actor runtime.

    Where ``EpochDriver`` *calls* miners and validators in lockstep, this
    driver never touches their compute: it publishes the epoch plan (the
    deterministic schedule every actor derives its work list from), the
    token/label batches, and then advances on watermark keys the actor
    processes publish — tick losses from last-stage miners, scores from
    validators, weight/shard uploads from qualifying miners.  The driver
    keeps only the genuinely central work: plan-time RNG, the dense
    golden-oracle reduce (or sharded anchor assembly), the DiLoCo outer
    step, the ledger, and store GC.

    Determinism: every swarm RNG draw (per-tick availability rolls +
    pathway sampling, then validator assignment) happens at plan time in
    exactly the lockstep order, and actors interact only through
    bit-exact store payloads, so dense and sharded runs reproduce the
    in-process loss trajectory at the same seed.  Fault behaviors that
    corrupt *payloads* (tamper, free-ride) are driver-side in the
    lockstep timeline and are rejected by ``ActorSwarm``; drop/straggle
    are schedule-only and fully supported.

    ``swarm.check_liveness`` (when present) is consulted while polling so
    a crashed actor surfaces as ``ActorDied`` instead of a timeout.

    Graceful degradation (docs/CHAOS.md): with a KeySchema v4 transport
    an ``ActorDied`` mid-epoch is survivable — the driver re-plans the
    dead miner's remaining ticks onto a stage survivor and publishes the
    revision under ``control/ep{E}/plan/r{R}`` (actors poll for it while
    blocked); a dead validator just forfeits its score; a reducer lost
    during the sharded merge fails over to the surviving redundant
    copy's partner (the §5.2 redundancy — honest copies are
    bit-identical, so the anchor stays bit-exact).
    """

    failover_grace = 5.0     # partner-copy patience once one copy landed

    def __init__(self, poll_interval: float = 0.002, timeout: float = 120.0):
        super().__init__()
        self.phases = []            # the timeline is event-driven, not phased
        self.poll_interval = poll_interval
        self.timeout = timeout
        self._ctl_floor = 0         # first epoch whose control keys remain
        self._plan: dict = {}       # latest plan (incl. revisions) in flight
        self._plan_rev = 0
        self._dead_validators: set = set()

    # -- store polling ---------------------------------------------------

    def _await(self, swarm, key: str,
               timeout: Optional[float] = None) -> None:
        tp = swarm.transport
        check = getattr(swarm, "check_liveness", None)
        wait_for = getattr(tp, "wait_for", None)
        budget = self.timeout if timeout is None else timeout
        deadline = time.monotonic() + budget
        polls = 0
        while True:
            if check is not None and polls % 25 == 0:
                check()
            if wait_for is not None:
                # park server-side (zero CPU) in bounded slices so the
                # liveness check still runs between them
                slice_s = min(0.25, max(budget, 0.01))
                if wait_for(key, timeout=slice_s, actor="orchestrator"):
                    return
                polls += 25          # one slice ~ a liveness interval
            else:
                if tp.exists(key):
                    return
                time.sleep(self.poll_interval)
                polls += 1
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"event driver timed out after {budget}s "
                    f"awaiting {key!r}")

    # -- graceful degradation --------------------------------------------

    @staticmethod
    def _death_of(err: Exception) -> Optional[str]:
        """Duck-typed ``ActorDied`` detection (the actor module imports
        this one; importing it back at module level would be circular)."""
        name = getattr(err, "actor", None)
        return name if isinstance(err, RuntimeError) and name else None

    def _handle_actor_death(self, swarm, state: EpochState,
                            err: Exception) -> None:
        """Re-plan around a dead actor instead of aborting the epoch.

        Dead validator: forget it, forfeit its score.  Dead miner:
        compute a :func:`revise_plan` revision from the store's tick-loss
        watermarks, publish it under ``plan_rev`` for blocked actors, and
        rewrite the driver's own tick table.  Raises the original error
        when the transport cannot carry revisions (schema < v4)."""
        name = self._death_of(err)
        supervisor = getattr(swarm, "supervisor", None)
        if supervisor is not None:
            supervisor.forget(name)
        if not name.startswith("miner"):
            self._dead_validators.add(name)
            return
        uid = int(name[len("miner"):])
        dead_uids = getattr(swarm, "dead_uids", None)
        if dead_uids is not None:
            dead_uids.add(uid)
        tp, schema = swarm.transport, swarm.transport.schema
        if schema.version < 4:
            raise err            # no revision channel: fail loudly
        plan = self._plan
        if uid in plan.get("dead", ()):
            return               # already re-planned around this miner
        epoch = state.epoch
        done = {t for t, _u, _g in self._ticks
                if tp.exists(schema.tick_loss(epoch, t))}
        stage = plan["stage_of"][uid]
        known_dead = set(plan.get("dead", ())) | {uid}
        alive = sorted(u for u, st in plan["stage_of"].items()
                       if st == stage and u not in known_dead)
        survivor = alive[0] if alive else None
        revision, n_replanned, _orphaned, dropped = revise_plan(
            plan, done, uid, survivor,
            gradient_missing=lambda t, uids: not tp.exists(
                schema.gradient(epoch, t, stage - 1, uids[stage - 1])))
        self._plan_rev += 1
        revision["rev"] = self._plan_rev
        tp.put(schema.plan_rev(epoch, self._plan_rev), revision,
               actor="orchestrator")
        self._plan = revision
        state.replanned += n_replanned
        # rewrite the driver's tick table: substituted pathways keep their
        # slot (the survivor's loss arrives under the same tick key),
        # dropped ticks leave the await loop as stalled
        by_tick = {t: tuple(uids) for t, uids in revision["ticks"]}
        new_ticks = []
        for t, _uids, gt in self._ticks:
            if t in dropped:
                state.stalled += 1
                continue
            new_ticks.append((t, by_tick[t], gt))
        self._ticks = new_ticks

    # -- the timeline ----------------------------------------------------

    def run_epoch(self, swarm) -> EpochStats:
        S = swarm.config
        tp, schema = swarm.transport, swarm.transport.schema
        if schema.version < 3:
            raise ValueError(
                "EventDriver needs a KeySchema v3 transport (control-plane "
                f"keys); got v{schema.version}")
        epoch = swarm.epoch
        for m in swarm.miners.values():
            m.reset_epoch()             # parent-side handles: census hygiene
        state = EpochState(epoch=epoch, snapshots={})

        plan = self._build_plan(swarm, state)
        self._plan = plan
        self._plan_rev = 0
        tp.publish(EpochPlanMsg(epoch), plan, actor="orchestrator")
        for tick, _uids, gt in self._ticks:
            batch = swarm.corpus.batch(gt)
            tp.publish(ActivationMsg.tokens(epoch, tick),
                       jnp.asarray(batch["tokens"]), actor="orchestrator")
            tp.publish(LabelsMsg(epoch, tick),
                       jnp.asarray(batch["labels"]), actor="orchestrator")

        # training watermarks: fold tick losses into PathwayRecords in tick
        # order (actors may publish out of order; the records must not).
        # An ActorDied surfaced by the liveness hook re-plans and retries
        # the same slot — self._ticks may shrink (dropped) or be rewritten
        # (survivor substitution) under us
        i = 0
        while i < len(self._ticks):
            tick, uids, _gt = self._ticks[i]
            key = TickLossMsg(epoch, tick).key(schema)
            try:
                self._await(swarm, key)
            except RuntimeError as err:
                if self._death_of(err) is None:
                    raise
                self._handle_actor_death(swarm, state, err)
                continue
            state.records.append(clasp.PathwayRecord(
                self._ticks[i][1],
                float(tp.get(key, actor="orchestrator"))))
            i += 1

        self._collect_scores(swarm, state, self._plan)

        if state.merge_quorum:
            for s in sorted(self._plan["qualified"]):
                quids = tuple(self._plan["qualified"][s])
                while True:
                    try:
                        if S.sync_mode == "sharded":
                            merged = self._reduce_sharded(swarm, state, s,
                                                          quids)
                        else:
                            merged = self._reduce_dense(swarm, state, s,
                                                        quids)
                    except RuntimeError as err:
                        if self._death_of(err) is None:
                            raise
                        self._handle_actor_death(swarm, state, err)
                        continue     # retry: dead uploads are now masked
                    if merged is None:
                        # every qualifier died pre-upload: republish the
                        # unchanged anchor so survivors parked on the
                        # full-sync download still unblock
                        anchor_vec, _ = ravel_pytree(jax.tree.map(
                            lambda x: x.astype(jnp.float32),
                            swarm.anchors[s]))
                        swarm.transport.publish(
                            AnchorMsg(state.epoch, s),
                            np.asarray(anchor_vec), actor="orchestrator")
                    else:
                        self._outer_step_and_publish(swarm, state, s,
                                                     merged)
                    break
            for s in sorted(state.executors):
                for v in swarm.validators:
                    state.reduce_audits.append(v.audit_reduce(epoch, s))

        stats = self._finalize(swarm, state)
        # control-plane GC is a pinned floor like the weight/score planes:
        # a crash-resume replay needs the plans/revisions back to its
        # snapshot epoch, so respawns pin the floor (pin_retention) and
        # the sweep stops there until released
        pin = self._pin_floor()
        limit = stats.epoch + 1
        if pin is not None:
            limit = min(limit, pin)
        while self._ctl_floor < limit:
            tp.delete_prefix(schema.control_prefix(self._ctl_floor))
            self._ctl_floor += 1
        return stats

    # -- plan construction (all swarm RNG, lockstep order) ---------------

    def _build_plan(self, swarm, state: EpochState) -> dict:
        S = swarm.config
        # miners that died in earlier epochs and have not respawned are
        # not schedulable; the availability roll still happens for them so
        # the RNG stream (and the no-death trajectory) is unchanged
        dead = getattr(swarm, "dead_uids", None) or set()
        ticks = []
        for tick in range(S.inner_steps):
            gt = swarm.global_tick      # the batch index, like the lockstep
            swarm.global_tick += 1      # driver consumes it even when stalled
            pathway = []
            ok = True
            for s in range(S.n_stages):
                avail = [m for m in swarm.stage_miners(s)
                         if swarm.available(m, tick)
                         and m.uid not in dead]
                if not avail:
                    ok = False
                    break
                pathway.append(avail[swarm.rng.randint(len(avail))].uid)
            if not ok:
                state.stalled += 1
                continue
            ticks.append((tick, tuple(pathway), gt))
        self._ticks = ticks

        batches = {uid: 0 for uid in swarm.miners}
        for _tick, uids, _gt in ticks:
            for uid in uids:
                batches[uid] += 1
        state.batches = batches
        state.b_eff = diloco.effective_batch(batches, S.b_min)
        state.merge_quorum = diloco.should_merge(batches, S.b_min,
                                                 S.quorum_frac)
        qualified: dict[int, tuple] = {}
        if state.merge_quorum:
            for s in range(S.n_stages):
                qual = tuple(m.uid for m in swarm.stage_miners(s)
                             if batches[m.uid] >= S.b_min)
                if len(qual) >= 2:
                    qualified[s] = qual

        # validator assignment draws come after every training draw —
        # identical RNG order to the lockstep ValidationPhase
        uids_sorted = sorted(swarm.miners)
        alive_sorted = [u for u in uids_sorted if u not in dead]
        tracked = {}
        if uids_sorted:
            for v in swarm.validators:
                # draw over the full census (RNG parity), then remap a
                # dead pick to a live miner — a validator must never be
                # assigned a peer that cannot publish a snapshot
                uid = uids_sorted[swarm.rng.randint(len(uids_sorted))]
                if uid in dead:
                    if not alive_sorted:
                        continue
                    uid = alive_sorted[uid % len(alive_sorted)]
                tracked[v.uid] = uid

        return {
            "stop": False,
            "epoch": state.epoch,
            "ticks": tuple((t, uids) for t, uids, _gt in ticks),
            "merge": state.merge_quorum,
            "qualified": qualified,
            "tracked": tracked,
            "stage_of": {uid: swarm.miners[uid].stage
                         for uid in uids_sorted},
        }

    # -- validation watermarks -------------------------------------------

    def _collect_scores(self, swarm, state: EpochState, plan: dict) -> None:
        from repro.runtime.validator import ValidationResult
        schema = swarm.transport.schema
        t_now = state.epoch * swarm.config.sync_interval_hours
        for v in swarm.validators:
            uid = plan["tracked"].get(v.uid)
            if uid is None:
                continue
            msg = ScoreMsg(state.epoch, v.uid, uid)
            while True:
                if f"validator{v.uid}" in self._dead_validators:
                    break            # died mid-replay: score forfeited
                try:
                    self._await(swarm, msg.key(schema))
                except RuntimeError as err:
                    if self._death_of(err) is None:
                        raise
                    # a death elsewhere in the fleet: re-plan (the
                    # validator publishes a partial score if its tracked
                    # miner is the casualty) and keep waiting
                    self._handle_actor_death(swarm, state, err)
                    continue
                vec = np.asarray(swarm.transport.fetch(
                    msg, actor="orchestrator"))
                res = ValidationResult(uid, state.epoch, int(vec[1]),
                                       int(vec[2]), float(vec[0]),
                                       float(vec[3]))
                v.results.append(res)
                swarm.ledger.record(uid, state.epoch, res.score, t_now)
                state.validation.append(res)
                break

    # -- merge: await uploads, reduce, outer step, publish anchor --------

    def _stage_vec_len(self, swarm, s: int) -> int:
        vec, _ = ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32), swarm.anchors[s]))
        return int(vec.shape[0])

    def _reduce_dense(self, swarm, state: EpochState, s: int,
                      quids: tuple) -> Optional[np.ndarray]:
        S = swarm.config
        schema = swarm.transport.schema
        vec_len = self._stage_vec_len(swarm, s)
        # the merge layout is fixed at plan time (revise_plan never
        # rewrites ``qualified``): a dead qualifier is *masked*, not
        # relaid — its upload is used if it landed before the crash,
        # skipped otherwise, and the butterfly's masked mean averages
        # whatever arrived
        dead = set(self._plan.get("dead", ()))
        uploads: dict[int, np.ndarray] = {}
        for idx, uid in enumerate(quids):
            msg = WeightUploadMsg(state.epoch, s, uid, codec=S.share_codec)
            key = msg.key(schema)
            if uid in dead and not swarm.transport.exists(key):
                continue
            self._await(swarm, key)
            payload = swarm.transport.fetch(msg, actor="orchestrator")
            uploads[idx] = np.asarray(compression.decode(payload, vec_len))
        if not uploads:
            return None          # every qualifier died before uploading
        plan = butterfly.make_plan(len(quids), vec_len,
                                   seed=S.seed + state.epoch * 131 + s)
        copies = butterfly.reduce_with_copies(plan, uploads)
        state.agreement[s] = butterfly.agreement_matrix(plan, copies)
        merged, _, _ = butterfly.reduce_shards(plan, uploads)
        return merged

    def _reduce_sharded(self, swarm, state: EpochState, s: int,
                        quids: tuple) -> np.ndarray:
        S = swarm.config
        tp = swarm.transport
        vec_len = self._stage_vec_len(swarm, s)
        align = compression.INT8_BLOCK if S.share_codec == "int8" else 1
        plan = butterfly.make_plan(len(quids), vec_len,
                                   seed=S.seed + state.epoch * 131 + s,
                                   align=align)
        ex = butterfly.ButterflyExecutor(
            plan, swarm.transport, epoch=state.epoch, stage=s,
            uids=list(quids), codec=S.share_codec)
        # reducer failover (§5.2 redundancy): each shard has two
        # independent reduced copies.  The first copy gets the full
        # timeout; once one landed, its partner only gets a short grace —
        # a reducer lost to a crash or a dropped put costs seconds, not
        # the epoch.  Honest copies are bit-identical, so collect()
        # assembling from the survivor keeps the anchor bit-exact.
        # a reducer that died back in the tick phase is already in the
        # plan's dead list — seed the failover set so its never-coming
        # copy gets an exists-check, not a full-timeout await
        dead_idx: set = {quids.index(u)
                         for u in self._plan.get("dead", ())
                         if u in quids}
        for shard, (i, j) in enumerate(plan.pairs):
            lo, hi = plan.shard_bounds(shard)
            if hi == lo:
                continue
            have = 0
            for r in (i, j):
                key = ex.reduced_key(shard, r)
                if r in dead_idx:
                    have += int(tp.exists(key))   # published before dying?
                    continue
                try:
                    self._await(swarm, key,
                                timeout=self.failover_grace if have
                                else None)
                    have += 1
                except TimeoutError:
                    if have == 0:
                        raise        # neither copy: the merge is truly stuck
                    # partner never arrived: fail over to the copy we have
                except RuntimeError as err:
                    name = self._death_of(err)
                    if name is None:
                        raise
                    self._handle_actor_death(swarm, state, err)
                    if name.startswith("miner"):
                        uid = int(name[len("miner"):])
                        if uid in quids:
                            dead_idx.add(quids.index(uid))
                    have += int(tp.exists(key))
            if have == 0:
                raise TimeoutError(
                    f"both reduced copies of stage {s} shard {shard} are "
                    f"lost (reducers {i} and {j}): cannot assemble anchor")
        merged, _, _ = ex.collect(actor="orchestrator")
        state.agreement[s] = ex.last_agreement
        state.executors[s] = ex
        return merged

    def _outer_step_and_publish(self, swarm, state: EpochState, s: int,
                                merged: np.ndarray) -> None:
        S = swarm.config
        _, unravel = ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32), swarm.anchors[s]))
        avg = unravel(jnp.asarray(merged))
        swarm.outer[s] = diloco.outer_update(
            swarm.outer[s], avg, outer_lr=S.outer_lr,
            outer_momentum=S.outer_momentum)
        swarm.anchors[s] = jax.tree.map(
            lambda a, p: a.astype(p.dtype), swarm.outer[s].anchor,
            swarm.anchors[s])
        anchor_vec, _ = ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32), swarm.anchors[s]))
        # actors download the anchor themselves (the plan tells them which
        # stages merge); the driver only publishes it
        swarm.transport.publish(AnchorMsg(state.epoch, s),
                                np.asarray(anchor_vec), actor="orchestrator")
        state.merged_stages += 1


# ---------------------------------------------------------------------------
# Serve plane: inference as a pipeline workload (docs/SERVE.md)
# ---------------------------------------------------------------------------
#
# The decode timetable (``compile_timetable("decode", P, n_lanes)``) is the
# single source of execution order: micro-batch slots are *request lanes*,
# and one "round" advances every active lane by one token.  The driver does
# continuous batching — it admits queued requests into free lanes and
# retires finished ones strictly *between* rounds, publishing one lane plan
# per round, so the per-slot stage work (and any jitted callable behind it)
# never changes shape and never recompiles.  Stage compute is a
# ``StageServer`` (one per stage): in-process and socket runs call them
# synchronously in timetable slot order; ``runtime="actors"`` fleets run
# the identical object inside ``ServeActor`` processes driven by the same
# round plans.  Sampling stays in the driver, so stage actors are pure
# deterministic functions of store payloads and greedy decode is
# token-for-token reproducible against the sequential oracle.


@dataclasses.dataclass
class ServeRequest:
    """One inference request: a prompt plus sampling parameters.

    ``arrival_round`` is the earliest decode round the scheduler may admit
    it (0 = available immediately) — tests use it to stagger mid-flight
    admissions deterministically."""
    req: int
    prompt: Any                  # (S,) int token ids (list or array)
    max_new: int = 16
    temperature: float = 0.0
    arrival_round: int = 0


@dataclasses.dataclass
class RequestRecord:
    """Per-request serving record: emitted tokens + latency breakdown."""
    req: int
    tokens: list = dataclasses.field(default_factory=list)
    submit_s: float = 0.0
    first_token_s: Optional[float] = None     # TTFT (prefill + first sample)
    done_s: Optional[float] = None
    token_s: list = dataclasses.field(default_factory=list)  # per-token stamps

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submit_s

    @property
    def total(self) -> Optional[float]:
        if self.done_s is None:
            return None
        return self.done_s - self.submit_s


def _serve_await(tp, key: str, *, actor: str, timeout: float = 120.0,
                 poll: float = 0.002):
    """Blocking store read for the serve plane: server-side park when the
    transport supports it (SocketTransport ``wait_for``), polling
    otherwise."""
    wait_for = getattr(tp, "wait_for", None)
    deadline = time.monotonic() + timeout
    while not tp.exists(key):
        if time.monotonic() > deadline:
            raise TimeoutError(f"serve: timed out awaiting {key!r}")
        if wait_for is not None:
            wait_for(key, timeout=0.25, actor=actor)
        else:
            time.sleep(poll)
    return tp.get(key, actor=actor)


class StageServer:
    """One stage's serve-side worker: a ``StageProgram`` + params + one
    stage-local KV cache per request lane.

    ``process_slot`` executes one (round, lane) timetable cell: fetch the
    stage input from the store (prompt tokens / last sampled token on the
    first stage, the upstream boundary code elsewhere), advance the lane's
    cache through the slice, publish the boundary output.  Identical code
    runs in-process under the ``ServeDriver`` and inside ``ServeActor``
    processes — the store payloads are the only interface, so every
    transport serves bit-identical tokens."""

    def __init__(self, spec, stage: int, params, *, n_lanes: int,
                 max_len: int, wire_codec: str = "none"):
        from repro.runtime import stage_model as sm
        self.program = sm.StageProgram(spec, stage, wire_codec)
        self.stage = stage
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.caches = [self.program.init_cache(1, max_len)
                       for _ in range(n_lanes)]
        self.slots_done = 0

    @property
    def actor(self) -> str:
        return f"server{self.stage}"

    def reset_lane(self, lane: int) -> None:
        """Admission: the lane's cache restarts from length 0 — lanes are
        independent batch rows, so this cannot perturb other lanes."""
        self.caches[lane] = self.program.init_cache(1, self.max_len)

    def process_slot(self, tp, schema, round_: int, entry: dict) -> None:
        lane, req = int(entry["lane"]), int(entry["req"])
        prefill = entry["phase"] == "prefill"
        if self.stage == 0:
            if prefill:
                env = _serve_await(tp, schema.serve_request(req),
                                   actor=self.actor)
                x = jnp.asarray(env["tokens"], jnp.int32)
            else:
                tok = _serve_await(
                    tp, schema.serve_token(req, int(entry["in_index"])),
                    actor=self.actor)
                x = jnp.asarray(tok, jnp.int32).reshape(1, 1)
        else:
            payload = _serve_await(
                tp, schema.serve_code(round_, lane, self.stage - 1),
                actor=self.actor)
            x = self.program.decode_wire(payload)
        if prefill:
            self.reset_lane(lane)
        out, self.caches[lane] = self.program.decode_step(
            self.params, x, self.caches[lane])
        if self.program.role in ("last", "solo"):
            # ship only the last position's logits: that is all sampling
            # needs, and it keeps the serve plane's store traffic O(vocab)
            # instead of O(prompt * vocab) on prefill rounds
            payload = {"code": np.asarray(out[:, -1], np.float32)}
        else:
            payload = self.program.encode_wire(out)
        tp.publish(ServeCodeMsg(round_, lane, self.stage), payload,
                   actor=self.actor)
        self.slots_done += 1


@dataclasses.dataclass
class _Lane:
    """Driver-side state of one occupied request lane."""
    req: int
    max_new: int
    temperature: float
    emitted: int = 0           # tokens sampled so far (== next token index)


class ServeDriver:
    """Continuous-batching decode driver over any ``Transport``.

    The driver owns admission/retirement, sampling and latency tracking;
    stage compute lives in ``StageServer``s.  With ``servers`` given (the
    in-process and socket paths) the driver executes every timetable slot
    itself, in compiled slot order; with ``servers=None`` (actor fleets)
    it only publishes round plans and awaits each lane's last-stage
    logits, while ``ServeActor`` processes execute the same slots.

    Greedy parity contract: at ``temperature=0`` the emitted tokens are
    bit-identical to ``launch.serve.swarm_generate`` (the sequential
    single-process oracle) at the same seed, for any stage count,
    transport, or admission order — lanes are independent batch rows and
    sampling keys fold (seed, req, index) only.
    """

    def __init__(self, spec, transport, *, n_lanes: int, max_len: int,
                 servers: Optional[list] = None, seed: int = 0,
                 wire_codec: str = "none", timeout: float = 120.0):
        from repro.core.pipeline import ROLE_F, compile_timetable
        self.spec = spec
        self.transport = transport
        self.schema = transport.schema
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.servers = servers
        self.seed = seed
        self.wire_codec = wire_codec
        self.timeout = timeout
        self.timetable = compile_timetable("decode", spec.n_stages, n_lanes)
        self._role_f = ROLE_F
        self.records: dict[int, RequestRecord] = {}
        self.rounds_run = 0

    # -- plumbing --------------------------------------------------------

    def publish_session_plan(self) -> None:
        """The one-shot session spec serve actors derive everything from."""
        self.transport.publish(ServePlanMsg(), {
            "n_stages": self.spec.n_stages,
            "n_lanes": self.n_lanes,
            "max_len": self.max_len,
            "wire_codec": self.wire_codec,
            "seed": self.seed,
        }, actor="serve-driver")

    def _sample(self, req: int, index: int, temperature: float, logits):
        from repro.runtime import stage_model as sm
        key = sm.request_key(self.seed, req, index)
        return int(np.asarray(sm.sample_token(
            jnp.asarray(logits), temperature=temperature, key=key))[0])

    # -- the round loop --------------------------------------------------

    def run(self, requests: Iterable[ServeRequest]) -> dict:
        """Serve every request to completion; returns {req: RequestRecord}.

        Admission and retirement happen strictly between rounds: a request
        joining mid-flight lands in a free lane as a *prefill* slot of the
        next round while already-running lanes decode — the lane plan is
        the active-lane mask, and untouched lanes' caches are untouched
        state, so running requests' tokens cannot change (the regression
        test pins this).
        """
        tp, schema = self.transport, self.schema
        queue = sorted(requests, key=lambda r: (r.arrival_round, r.req))
        lanes: list[Optional[_Lane]] = [None] * self.n_lanes
        self.publish_session_plan()
        rnd = self.rounds_run
        while queue or any(lanes):
            entries = []
            # admission: free lanes pick up arrived requests (FIFO)
            for li in range(self.n_lanes):
                if lanes[li] is None and queue \
                        and queue[0].arrival_round <= rnd:
                    r = queue.pop(0)
                    prompt = np.asarray(r.prompt, np.int32).reshape(1, -1)
                    assert prompt.shape[1] + r.max_new <= self.max_len, (
                        "prompt + max_new exceeds the lane KV capacity")
                    tp.publish(ServeRequestMsg(r.req), {
                        "tokens": prompt,
                        "max_new": int(r.max_new),
                        "temperature": float(r.temperature),
                    }, actor="serve-driver")
                    rec = self.records.setdefault(r.req, RequestRecord(r.req))
                    rec.submit_s = time.perf_counter()
                    lanes[li] = _Lane(r.req, int(r.max_new),
                                      float(r.temperature))
                    entries.append({"lane": li, "req": r.req,
                                    "phase": "prefill"})
                elif lanes[li] is not None:
                    ln = lanes[li]
                    entries.append({"lane": li, "req": ln.req,
                                    "phase": "decode",
                                    "in_index": ln.emitted - 1})
            if not entries:
                # nothing admissible yet (future arrival_round): publish
                # the empty round anyway so actor fleets stay in lockstep
                # with the driver's round counter (not GC'd — a late actor
                # may still need to read it; it is tiny and session-scoped)
                tp.publish(ServeRoundPlanMsg(rnd),
                           {"entries": [], "stop": False},
                           actor="serve-driver")
                rnd += 1
                continue
            tp.publish(ServeRoundPlanMsg(rnd),
                       {"entries": entries, "stop": False},
                       actor="serve-driver")
            if self.servers is not None:
                self._run_slots(rnd, entries)
            self._collect(rnd, entries, lanes)
            tp.delete_prefix(schema.serve_round_prefix(rnd))
            rnd += 1
        self.rounds_run = rnd
        return self.records

    def _run_slots(self, rnd: int, entries: list) -> None:
        """Execute one round's cells in compiled timetable order: slot t,
        stage s acts on lane ``micro[s, t]`` iff the lane plan marks that
        lane active.  This is the store-and-forward realization of the
        decode schedule — the same (s, lane) dependency order the on-mesh
        ``lax.switch`` executor walks."""
        tt = self.timetable
        by_lane = {e["lane"]: e for e in entries}
        for t in range(tt.n_slots):
            for s in range(tt.n_stages):
                if int(tt.role[s, t]) != self._role_f:
                    continue
                entry = by_lane.get(int(tt.micro[s, t]))
                if entry is None:
                    continue          # inactive lane: masked-off cell
                self.servers[s].process_slot(
                    self.transport, self.schema, rnd, entry)

    def _collect(self, rnd: int, entries: list, lanes: list) -> None:
        """Fetch each active lane's last-stage logits, sample, publish the
        token, retire finished requests."""
        tp, schema = self.transport, self.schema
        last = self.spec.n_stages - 1
        for entry in entries:
            li = int(entry["lane"])
            ln = lanes[li]
            payload = _serve_await(
                tp, schema.serve_code(rnd, li, last),
                actor="serve-driver", timeout=self.timeout)
            tok = self._sample(ln.req, ln.emitted, ln.temperature,
                               payload["code"])
            rec = self.records[ln.req]
            now = time.perf_counter()
            tp.publish(ServeTokenMsg(ln.req, ln.emitted),
                       np.asarray([[tok]], np.int32), actor="serve-driver")
            rec.tokens.append(tok)
            rec.token_s.append(now)
            if rec.first_token_s is None:
                rec.first_token_s = now
            ln.emitted += 1
            if ln.emitted >= ln.max_new:
                rec.done_s = now
                tp.publish(ServeDoneMsg(ln.req), {
                    "n_tokens": ln.emitted,
                    "ttft_s": rec.ttft,
                    "total_s": rec.total,
                }, actor="serve-driver")
                lanes[li] = None

    def stop_fleet(self) -> None:
        """Tell ServeActor processes the session is over (a stop plan in
        the next round slot)."""
        self.transport.publish(
            ServeRoundPlanMsg(self.rounds_run),
            {"entries": [], "stop": True}, actor="serve-driver")
