"""Pluggable transports: how messages physically reach the shared store.

The paper's hub-and-spoke network (§2, Fig 6) routes everything through a
globally accessible store; *how long* that takes is a property of each
actor's link, not of the algorithm.  The ``Transport`` protocol is the seam:

  * ``InProcessTransport``      zero-latency wrapper over ``StateStore`` —
    bit-identical to the seed runtime (same accounting, same digests, same
    trajectory).
  * ``SimulatedNetworkTransport``  the same store plus a per-link
    latency/bandwidth model that accumulates *simulated* wall-clock, so
    benchmarks can report time-to-loss under realistic links (§5.3
    transfer analysis, scenario-parameterised).
  * ``SocketTransport``           a real client of a ``StoreServer``
    process (``repro.runtime.store_server``): every payload crosses a
    length-prefixed TCP socket via the ``repro.api.serde`` wire format,
    digests preserved end-to-end, ``StoreKeyError`` re-raised from the
    server's response.  ``elapsed_seconds`` is *real* seconds spent
    blocked on the wire.

Clock model (documented, deliberately simple): every actor owns one full-
duplex link to the hub.  Transfers on the same link serialize; transfers on
different links overlap only inside an explicit ``transport.parallel()``
block (the phases mark weight upload / anchor download fan-outs that way —
the forward/backward activation chain is genuinely sequential).  The global
simulated clock advances by each transfer's duration, or by the *max*
duration inside a parallel block.

Missing keys surface as ``StoreKeyError`` (key + actor + nearest existing
prefix) through ``get``/``fetch`` on every transport.
"""
from __future__ import annotations

import contextlib
import dataclasses
import socket
import time
from collections import defaultdict
from typing import Any, Optional, Protocol, runtime_checkable

from repro.api import serde
from repro.api.keys import KeySchema
from repro.api.messages import Message
from repro.runtime.state_store import StateStore, StoreKeyError  # noqa: F401


@runtime_checkable
class Transport(Protocol):
    """What the runtime needs from a message plane.

    ``publish``/``fetch`` move typed messages; ``put``/``get`` move raw
    keys (validator replay walks logged keys).  ``elapsed_seconds`` is the
    simulated wall-clock spent on transfers (0.0 for in-process)."""

    schema: KeySchema

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str: ...
    def fetch(self, msg: Message, actor: str = "?") -> Any: ...
    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str: ...
    def get(self, key: str, actor: str = "?") -> Any: ...
    def exists(self, key: str) -> bool: ...
    def delete_prefix(self, prefix: str) -> int: ...
    def keys(self, prefix: str = "") -> list[str]: ...
    def parallel(self): ...
    def traffic_report(self) -> dict: ...
    def link_report(self) -> dict: ...
    def elapsed_seconds(self) -> float: ...


class InProcessTransport:
    """The seed behaviour: a dict lookup away, no latency, no bandwidth."""

    def __init__(self, store: Optional[StateStore] = None,
                 schema: Optional[KeySchema] = None):
        self.store = store or StateStore()
        self.schema = schema or KeySchema()

    # -- typed plane -----------------------------------------------------

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str:
        return self.put(msg.key(self.schema), payload, actor=actor, meta=meta)

    def fetch(self, msg: Message, actor: str = "?") -> Any:
        return self.get(msg.key(self.schema), actor=actor)

    # -- raw plane -------------------------------------------------------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        return self.store.put(key, value, actor=actor, codec=codec,
                              meta=meta).digest

    def get(self, key: str, actor: str = "?") -> Any:
        return self.store.get(key, actor=actor)

    def exists(self, key: str) -> bool:
        return self.store.exists(key)

    def delete_prefix(self, prefix: str) -> int:
        return self.store.delete_prefix(prefix)

    def keys(self, prefix: str = "") -> list[str]:
        return self.store.keys(prefix)

    # -- timing ----------------------------------------------------------

    @contextlib.contextmanager
    def parallel(self):
        yield

    def traffic_report(self) -> dict:
        return self.store.traffic_report()

    def link_report(self) -> dict:
        return {}

    def elapsed_seconds(self) -> float:
        return 0.0


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One actor's link to the hub."""
    latency_s: float = 0.02           # per-request round-trip setup
    bandwidth_mbps: float = 100.0     # megabits/second, symmetric

    def transfer_seconds(self, nbytes: int) -> float:
        return self.latency_s + (nbytes * 8.0) / (self.bandwidth_mbps * 1e6)


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Per-actor link overrides on top of a default link.

    Presets mirror the §5.3 scenarios: ``datacenter`` (what the paper's
    centralized baseline assumes) vs ``consumer`` (what a permissionless
    swarm actually gets)."""
    default: LinkSpec = LinkSpec()
    links: dict = dataclasses.field(default_factory=dict)  # actor -> LinkSpec

    def link(self, actor: str) -> LinkSpec:
        return self.links.get(actor, self.default)

    @classmethod
    def datacenter(cls) -> "NetworkModel":
        return cls(default=LinkSpec(latency_s=0.001, bandwidth_mbps=10_000.0))

    @classmethod
    def consumer(cls) -> "NetworkModel":
        return cls(default=LinkSpec(latency_s=0.03, bandwidth_mbps=100.0))


@dataclasses.dataclass
class LinkStats:
    up_bytes: int = 0
    down_bytes: int = 0
    busy_seconds: float = 0.0
    transfers: int = 0


class SimulatedNetworkTransport(InProcessTransport):
    """Same store, same payloads, same trajectory — plus a simulated clock.

    Byte accounting per link equals ``StateStore.traffic_report()``'s
    per-actor accounting by construction (both count ``StoreEntry.nbytes``
    on the same calls); tests assert the invariant."""

    def __init__(self, network: Optional[NetworkModel] = None,
                 store: Optional[StateStore] = None,
                 schema: Optional[KeySchema] = None):
        super().__init__(store=store, schema=schema)
        self.network = network or NetworkModel()
        self.links: dict[str, LinkStats] = defaultdict(LinkStats)
        self._clock = 0.0
        self._parallel_batch: Optional[dict[str, float]] = None

    # -- clock -----------------------------------------------------------

    def _charge(self, actor: str, nbytes: int, up: bool) -> None:
        seconds = self.network.link(actor).transfer_seconds(nbytes)
        stats = self.links[actor]
        stats.busy_seconds += seconds
        stats.transfers += 1
        if up:
            stats.up_bytes += nbytes
        else:
            stats.down_bytes += nbytes
        if self._parallel_batch is not None:
            self._parallel_batch[actor] = \
                self._parallel_batch.get(actor, 0.0) + seconds
        else:
            self._clock += seconds

    @contextlib.contextmanager
    def parallel(self):
        """Transfers inside the block overlap *across* links only: per the
        clock model, same-link transfers still serialize, so the clock
        advances by the busiest link's total.  Nested blocks flatten into
        the outermost."""
        if self._parallel_batch is not None:
            yield                      # already inside a batch
            return
        self._parallel_batch = {}
        try:
            yield
        finally:
            batch, self._parallel_batch = self._parallel_batch, None
            if batch:
                self._clock += max(batch.values())

    def elapsed_seconds(self) -> float:
        return self._clock

    def link_report(self) -> dict:
        return {actor: dataclasses.asdict(s)
                for actor, s in sorted(self.links.items())}

    # -- raw plane (timed; one store lookup per op — StateStore.put/
    # fetch_entry return the entry, so the hot loop never re-reads) --------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        entry = self.store.put(key, value, actor=actor, codec=codec,
                               meta=meta)
        self._charge(actor, entry.nbytes, up=True)
        return entry.digest

    def get(self, key: str, actor: str = "?") -> Any:
        entry = self.store.fetch_entry(key, actor=actor)
        self._charge(actor, entry.nbytes, up=False)
        return entry.payload


class SocketTransport:
    """Client of a real ``StoreServer`` (``repro.runtime.store_server``):
    the store lives in another process (or host), every payload crosses a
    length-prefixed TCP socket as a ``repro.api.serde`` frame.

    Parity contract with the in-process transports:

      * payloads round-trip bit-exactly and the server digests the *same*
        bytes, so digests equal the in-process run's;
      * the server's ``StateStore`` does the authoritative byte
        accounting per actor — for the same run it matches
        ``SimulatedNetworkTransport``'s link accounting by construction
        (both count ``StoreEntry.nbytes`` on the same calls);
      * a missing key raises the *same* ``StoreKeyError`` (key, actor,
        nearest existing prefix), reconstructed from the server's error
        response.

    ``link_report`` mirrors the simulated transport's shape with
    client-side counters (payload bytes per actor, *real* busy seconds);
    ``wire_report`` additionally counts raw socket bytes including
    framing/envelope overhead.  ``parallel()`` is a no-op: one TCP
    connection serializes requests (per-actor connections are future
    work), which is honest — ``elapsed_seconds`` is wall-clock actually
    spent blocked on the wire.
    """

    def __init__(self, address: tuple, schema: Optional[KeySchema] = None,
                 connect_timeout: float = 10.0):
        self.address = (str(address[0]), int(address[1]))
        self.schema = schema or KeySchema()
        self.connect_timeout = connect_timeout
        self._sock: Optional[socket.socket] = None
        self.links: dict[str, LinkStats] = defaultdict(LinkStats)
        self._elapsed = 0.0
        self._wire_up = 0
        self._wire_down = 0
        self._requests = 0

    # -- connection ------------------------------------------------------

    def _connect(self) -> socket.socket:
        """Dial with retries inside ``connect_timeout``: the server process
        may still be binding when the first request goes out."""
        deadline = time.monotonic() + self.connect_timeout
        while True:
            try:
                sock = socket.create_connection(self.address, timeout=30.0)
                sock.settimeout(None)   # the 30s covers dialing only: a
                # large transfer on a slow link may legitimately take longer
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                return sock
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.05)

    def _request(self, req: dict) -> dict:
        if self._sock is None:
            self._sock = self._connect()
        body = serde.dumps(req)
        t0 = time.monotonic()
        try:
            self._wire_up += serde.send_frame(self._sock, body)
            resp_body = serde.recv_frame(self._sock)
        except OSError:
            # a failed send/recv leaves the stream desynchronized: drop the
            # connection so a retry dials fresh instead of pairing the next
            # request with a stale half-read response
            self.close()
            raise
        finally:
            self._elapsed += time.monotonic() - t0
        if resp_body is None:
            self.close()
            raise ConnectionError(
                f"store server {self.address} closed the connection")
        self._wire_down += len(resp_body) + 8
        self._requests += 1
        resp = serde.loads(resp_body)
        if resp.get("ok"):
            return resp
        if resp.get("error") == "StoreKeyError":
            raise StoreKeyError(resp["key"], resp["actor"],
                                resp["nearest_prefix"],
                                resp["nearest_count"])
        raise RuntimeError(
            f"store server error: {resp.get('error')}: "
            f"{resp.get('message', '')}")

    def _charge(self, actor: str, nbytes: int, seconds: float,
                up: bool) -> None:
        stats = self.links[actor]
        stats.busy_seconds += seconds
        stats.transfers += 1
        if up:
            stats.up_bytes += nbytes
        else:
            stats.down_bytes += nbytes

    # -- typed plane -----------------------------------------------------

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str:
        return self.put(msg.key(self.schema), payload, actor=actor, meta=meta)

    def fetch(self, msg: Message, actor: str = "?") -> Any:
        return self.get(msg.key(self.schema), actor=actor)

    # -- raw plane -------------------------------------------------------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        t0 = time.monotonic()
        resp = self._request({"op": "put", "key": key, "value": value,
                              "actor": actor, "codec": codec, "meta": meta})
        self._charge(actor, resp["nbytes"], time.monotonic() - t0, up=True)
        return resp["digest"]

    def get(self, key: str, actor: str = "?") -> Any:
        t0 = time.monotonic()
        resp = self._request({"op": "get", "key": key, "actor": actor})
        self._charge(actor, resp["nbytes"], time.monotonic() - t0, up=False)
        return resp["value"]

    def exists(self, key: str) -> bool:
        return self._request({"op": "exists", "key": key})["exists"]

    def delete_prefix(self, prefix: str) -> int:
        return self._request({"op": "delete_prefix",
                              "prefix": prefix})["deleted"]

    def keys(self, prefix: str = "") -> list[str]:
        return list(self._request({"op": "keys", "prefix": prefix})["keys"])

    # -- timing / accounting ---------------------------------------------

    @contextlib.contextmanager
    def parallel(self):
        yield

    def traffic_report(self) -> dict:
        """The *server-side* authoritative accounting."""
        return self._request({"op": "traffic_report"})["report"]

    def link_report(self) -> dict:
        return {actor: dataclasses.asdict(s)
                for actor, s in sorted(self.links.items())}

    def wire_report(self) -> dict:
        """Raw socket bytes (payload + serde envelope + framing)."""
        return {"up_bytes": self._wire_up, "down_bytes": self._wire_down,
                "requests": self._requests}

    def elapsed_seconds(self) -> float:
        return self._elapsed

    # -- lifecycle -------------------------------------------------------

    def ping(self) -> dict:
        return self._request({"op": "ping"})

    def reset_store(self) -> None:
        """Fresh server-side store + counters (one server, many runs)."""
        self._request({"op": "reset"})

    def stop_server(self) -> None:
        """Ask the server process to exit, then drop the connection."""
        try:
            self._request({"op": "shutdown"})
        finally:
            self.close()

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
