"""Pluggable transports: how messages physically reach the shared store.

The paper's hub-and-spoke network (§2, Fig 6) routes everything through a
globally accessible store; *how long* that takes is a property of each
actor's link, not of the algorithm.  The ``Transport`` protocol is the seam:

  * ``InProcessTransport``      zero-latency wrapper over ``StateStore`` —
    bit-identical to the seed runtime (same accounting, same digests, same
    trajectory).
  * ``SimulatedNetworkTransport``  the same store plus a per-link
    latency/bandwidth model that accumulates *simulated* wall-clock, so
    benchmarks can report time-to-loss under realistic links (§5.3
    transfer analysis, scenario-parameterised).

Clock model (documented, deliberately simple): every actor owns one full-
duplex link to the hub.  Transfers on the same link serialize; transfers on
different links overlap only inside an explicit ``transport.parallel()``
block (the phases mark weight upload / anchor download fan-outs that way —
the forward/backward activation chain is genuinely sequential).  The global
simulated clock advances by each transfer's duration, or by the *max*
duration inside a parallel block.

Missing keys surface as ``StoreKeyError`` (key + actor + nearest existing
prefix) through ``get``/``fetch`` on every transport.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import defaultdict
from typing import Any, Optional, Protocol, runtime_checkable

from repro.api.keys import KeySchema
from repro.api.messages import Message
from repro.runtime.state_store import StateStore, StoreKeyError  # noqa: F401


@runtime_checkable
class Transport(Protocol):
    """What the runtime needs from a message plane.

    ``publish``/``fetch`` move typed messages; ``put``/``get`` move raw
    keys (validator replay walks logged keys).  ``elapsed_seconds`` is the
    simulated wall-clock spent on transfers (0.0 for in-process)."""

    schema: KeySchema

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str: ...
    def fetch(self, msg: Message, actor: str = "?") -> Any: ...
    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str: ...
    def get(self, key: str, actor: str = "?") -> Any: ...
    def exists(self, key: str) -> bool: ...
    def delete_prefix(self, prefix: str) -> int: ...
    def keys(self, prefix: str = "") -> list[str]: ...
    def parallel(self): ...
    def traffic_report(self) -> dict: ...
    def link_report(self) -> dict: ...
    def elapsed_seconds(self) -> float: ...


class InProcessTransport:
    """The seed behaviour: a dict lookup away, no latency, no bandwidth."""

    def __init__(self, store: Optional[StateStore] = None,
                 schema: Optional[KeySchema] = None):
        self.store = store or StateStore()
        self.schema = schema or KeySchema()

    # -- typed plane -----------------------------------------------------

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str:
        return self.put(msg.key(self.schema), payload, actor=actor, meta=meta)

    def fetch(self, msg: Message, actor: str = "?") -> Any:
        return self.get(msg.key(self.schema), actor=actor)

    # -- raw plane -------------------------------------------------------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        return self.store.put(key, value, actor=actor, codec=codec,
                              meta=meta).digest

    def get(self, key: str, actor: str = "?") -> Any:
        return self.store.get(key, actor=actor)

    def exists(self, key: str) -> bool:
        return self.store.exists(key)

    def delete_prefix(self, prefix: str) -> int:
        return self.store.delete_prefix(prefix)

    def keys(self, prefix: str = "") -> list[str]:
        return self.store.keys(prefix)

    # -- timing ----------------------------------------------------------

    @contextlib.contextmanager
    def parallel(self):
        yield

    def traffic_report(self) -> dict:
        return self.store.traffic_report()

    def link_report(self) -> dict:
        return {}

    def elapsed_seconds(self) -> float:
        return 0.0


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One actor's link to the hub."""
    latency_s: float = 0.02           # per-request round-trip setup
    bandwidth_mbps: float = 100.0     # megabits/second, symmetric

    def transfer_seconds(self, nbytes: int) -> float:
        return self.latency_s + (nbytes * 8.0) / (self.bandwidth_mbps * 1e6)


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Per-actor link overrides on top of a default link.

    Presets mirror the §5.3 scenarios: ``datacenter`` (what the paper's
    centralized baseline assumes) vs ``consumer`` (what a permissionless
    swarm actually gets)."""
    default: LinkSpec = LinkSpec()
    links: dict = dataclasses.field(default_factory=dict)  # actor -> LinkSpec

    def link(self, actor: str) -> LinkSpec:
        return self.links.get(actor, self.default)

    @classmethod
    def datacenter(cls) -> "NetworkModel":
        return cls(default=LinkSpec(latency_s=0.001, bandwidth_mbps=10_000.0))

    @classmethod
    def consumer(cls) -> "NetworkModel":
        return cls(default=LinkSpec(latency_s=0.03, bandwidth_mbps=100.0))


@dataclasses.dataclass
class LinkStats:
    up_bytes: int = 0
    down_bytes: int = 0
    busy_seconds: float = 0.0
    transfers: int = 0


class SimulatedNetworkTransport(InProcessTransport):
    """Same store, same payloads, same trajectory — plus a simulated clock.

    Byte accounting per link equals ``StateStore.traffic_report()``'s
    per-actor accounting by construction (both count ``StoreEntry.nbytes``
    on the same calls); tests assert the invariant."""

    def __init__(self, network: Optional[NetworkModel] = None,
                 store: Optional[StateStore] = None,
                 schema: Optional[KeySchema] = None):
        super().__init__(store=store, schema=schema)
        self.network = network or NetworkModel()
        self.links: dict[str, LinkStats] = defaultdict(LinkStats)
        self._clock = 0.0
        self._parallel_batch: Optional[dict[str, float]] = None

    # -- clock -----------------------------------------------------------

    def _charge(self, actor: str, nbytes: int, up: bool) -> None:
        seconds = self.network.link(actor).transfer_seconds(nbytes)
        stats = self.links[actor]
        stats.busy_seconds += seconds
        stats.transfers += 1
        if up:
            stats.up_bytes += nbytes
        else:
            stats.down_bytes += nbytes
        if self._parallel_batch is not None:
            self._parallel_batch[actor] = \
                self._parallel_batch.get(actor, 0.0) + seconds
        else:
            self._clock += seconds

    @contextlib.contextmanager
    def parallel(self):
        """Transfers inside the block overlap *across* links only: per the
        clock model, same-link transfers still serialize, so the clock
        advances by the busiest link's total.  Nested blocks flatten into
        the outermost."""
        if self._parallel_batch is not None:
            yield                      # already inside a batch
            return
        self._parallel_batch = {}
        try:
            yield
        finally:
            batch, self._parallel_batch = self._parallel_batch, None
            if batch:
                self._clock += max(batch.values())

    def elapsed_seconds(self) -> float:
        return self._clock

    def link_report(self) -> dict:
        return {actor: dataclasses.asdict(s)
                for actor, s in sorted(self.links.items())}

    # -- raw plane (timed; one store lookup per op — StateStore.put/
    # fetch_entry return the entry, so the hot loop never re-reads) --------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        entry = self.store.put(key, value, actor=actor, codec=codec,
                               meta=meta)
        self._charge(actor, entry.nbytes, up=True)
        return entry.digest

    def get(self, key: str, actor: str = "?") -> Any:
        entry = self.store.fetch_entry(key, actor=actor)
        self._charge(actor, entry.nbytes, up=False)
        return entry.payload
