"""Pluggable transports: how messages physically reach the shared store.

The paper's hub-and-spoke network (§2, Fig 6) routes everything through a
globally accessible store; *how long* that takes is a property of each
actor's link, not of the algorithm.  The ``Transport`` protocol is the seam:

  * ``InProcessTransport``      zero-latency wrapper over ``StateStore`` —
    bit-identical to the seed runtime (same accounting, same digests, same
    trajectory).
  * ``SimulatedNetworkTransport``  the same store plus a per-link
    latency/bandwidth model that accumulates *simulated* wall-clock, so
    benchmarks can report time-to-loss under realistic links (§5.3
    transfer analysis, scenario-parameterised).
  * ``SocketTransport``           a real client of a ``StoreServer``
    process (``repro.runtime.store_server``): every payload crosses a
    length-prefixed TCP socket via the ``repro.api.serde`` wire format,
    digests preserved end-to-end, ``StoreKeyError`` re-raised from the
    server's response.  ``elapsed_seconds`` is *real* seconds spent
    blocked on the wire.

Clock model (documented, deliberately simple): every actor owns one full-
duplex link to the hub.  Transfers on the same link serialize; transfers on
different links overlap only inside an explicit ``transport.parallel()``
block (the phases mark weight upload / anchor download fan-outs that way —
the forward/backward activation chain is genuinely sequential).  The global
simulated clock advances by each transfer's duration, or by the *max*
duration inside a parallel block.

Missing keys surface as ``StoreKeyError`` (key + actor + nearest existing
prefix) through ``get``/``fetch`` on every transport.
"""
from __future__ import annotations

import contextlib
import dataclasses
import socket
import threading
import time
from collections import defaultdict, deque
from typing import Any, Optional, Protocol, Sequence, runtime_checkable

from repro.api import serde
from repro.api.keys import KeySchema
from repro.api.messages import Message
from repro.runtime.state_store import (  # noqa: F401
    StateStore, StoreKeyError, _digest, _nbytes,
)


@runtime_checkable
class Transport(Protocol):
    """What the runtime needs from a message plane.

    ``publish``/``fetch`` move typed messages; ``put``/``get`` move raw
    keys (validator replay walks logged keys).  ``elapsed_seconds`` is the
    simulated wall-clock spent on transfers (0.0 for in-process)."""

    schema: KeySchema

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str: ...
    def fetch(self, msg: Message, actor: str = "?") -> Any: ...
    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str: ...
    def get(self, key: str, actor: str = "?") -> Any: ...
    def exists(self, key: str) -> bool: ...
    def delete_prefix(self, prefix: str) -> int: ...
    def keys(self, prefix: str = "") -> list[str]: ...
    def parallel(self): ...
    def traffic_report(self) -> dict: ...
    def link_report(self) -> dict: ...
    def elapsed_seconds(self) -> float: ...


class InProcessTransport:
    """The seed behaviour: a dict lookup away, no latency, no bandwidth."""

    def __init__(self, store: Optional[StateStore] = None,
                 schema: Optional[KeySchema] = None):
        self.store = store or StateStore()
        self.schema = schema or KeySchema()

    # -- typed plane -----------------------------------------------------

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str:
        return self.put(msg.key(self.schema), payload, actor=actor, meta=meta)

    def fetch(self, msg: Message, actor: str = "?") -> Any:
        return self.get(msg.key(self.schema), actor=actor)

    # -- raw plane -------------------------------------------------------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        return self.store.put(key, value, actor=actor, codec=codec,
                              meta=meta).digest

    def get(self, key: str, actor: str = "?") -> Any:
        return self.store.get(key, actor=actor)

    def exists(self, key: str) -> bool:
        return self.store.exists(key)

    def delete_prefix(self, prefix: str) -> int:
        return self.store.delete_prefix(prefix)

    def keys(self, prefix: str = "") -> list[str]:
        return self.store.keys(prefix)

    # -- timing ----------------------------------------------------------

    @contextlib.contextmanager
    def parallel(self):
        yield

    def traffic_report(self) -> dict:
        return self.store.traffic_report()

    def link_report(self) -> dict:
        return {}

    def elapsed_seconds(self) -> float:
        return 0.0


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One actor's link to the hub."""
    latency_s: float = 0.02           # per-request round-trip setup
    bandwidth_mbps: float = 100.0     # megabits/second, symmetric

    def transfer_seconds(self, nbytes: int) -> float:
        return self.latency_s + (nbytes * 8.0) / (self.bandwidth_mbps * 1e6)


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Per-actor link overrides on top of a default link.

    Presets mirror the §5.3 scenarios: ``datacenter`` (what the paper's
    centralized baseline assumes) vs ``consumer`` (what a permissionless
    swarm actually gets)."""
    default: LinkSpec = LinkSpec()
    links: dict = dataclasses.field(default_factory=dict)  # actor -> LinkSpec

    def link(self, actor: str) -> LinkSpec:
        return self.links.get(actor, self.default)

    @classmethod
    def datacenter(cls) -> "NetworkModel":
        return cls(default=LinkSpec(latency_s=0.001, bandwidth_mbps=10_000.0))

    @classmethod
    def consumer(cls) -> "NetworkModel":
        return cls(default=LinkSpec(latency_s=0.03, bandwidth_mbps=100.0))


@dataclasses.dataclass
class LinkStats:
    up_bytes: int = 0
    down_bytes: int = 0
    busy_seconds: float = 0.0
    transfers: int = 0


class SimulatedNetworkTransport(InProcessTransport):
    """Same store, same payloads, same trajectory — plus a simulated clock.

    Byte accounting per link equals ``StateStore.traffic_report()``'s
    per-actor accounting by construction (both count ``StoreEntry.nbytes``
    on the same calls); tests assert the invariant."""

    def __init__(self, network: Optional[NetworkModel] = None,
                 store: Optional[StateStore] = None,
                 schema: Optional[KeySchema] = None):
        super().__init__(store=store, schema=schema)
        self.network = network or NetworkModel()
        self.links: dict[str, LinkStats] = defaultdict(LinkStats)
        self._clock = 0.0
        self._parallel_batch: Optional[dict[str, float]] = None

    # -- clock -----------------------------------------------------------

    def _charge(self, actor: str, nbytes: int, up: bool) -> None:
        seconds = self.network.link(actor).transfer_seconds(nbytes)
        stats = self.links[actor]
        stats.busy_seconds += seconds
        stats.transfers += 1
        if up:
            stats.up_bytes += nbytes
        else:
            stats.down_bytes += nbytes
        if self._parallel_batch is not None:
            self._parallel_batch[actor] = \
                self._parallel_batch.get(actor, 0.0) + seconds
        else:
            self._clock += seconds

    @contextlib.contextmanager
    def parallel(self):
        """Transfers inside the block overlap *across* links only: per the
        clock model, same-link transfers still serialize, so the clock
        advances by the busiest link's total.  Nested blocks flatten into
        the outermost."""
        if self._parallel_batch is not None:
            yield                      # already inside a batch
            return
        self._parallel_batch = {}
        try:
            yield
        finally:
            batch, self._parallel_batch = self._parallel_batch, None
            if batch:
                self._clock += max(batch.values())

    def elapsed_seconds(self) -> float:
        return self._clock

    def link_report(self) -> dict:
        return {actor: dataclasses.asdict(s)
                for actor, s in sorted(self.links.items())}

    # -- raw plane (timed; one store lookup per op — StateStore.put/
    # fetch_entry return the entry, so the hot loop never re-reads) --------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        entry = self.store.put(key, value, actor=actor, codec=codec,
                               meta=meta)
        self._charge(actor, entry.nbytes, up=True)
        return entry.digest

    def get(self, key: str, actor: str = "?") -> Any:
        entry = self.store.fetch_entry(key, actor=actor)
        self._charge(actor, entry.nbytes, up=False)
        return entry.payload


class _Conn:
    """One TCP connection to the store server plus its in-flight pipeline.

    ``pending`` holds requests whose frames are on the wire but whose
    responses have not been read yet (pipelined puts inside a
    ``parallel()`` block).  The lock makes each connection a thread-safe
    handle: an actor process can share its transport between its main
    loop and its health thread."""

    __slots__ = ("sock", "lock", "pending")

    def __init__(self):
        self.sock: Optional[socket.socket] = None
        self.lock = threading.RLock()
        self.pending: deque = deque()   # of _Pending


@dataclasses.dataclass
class _Pending:
    """A pipelined put awaiting its response."""
    req: dict
    actor: str
    digest: str        # predicted client-side; verified against the server
    nbytes: int


class SocketTransport:
    """Client of a real ``StoreServer`` (``repro.runtime.store_server``):
    the store lives in another process (or host), every payload crosses a
    length-prefixed TCP socket as a ``repro.api.serde`` frame.

    Parity contract with the in-process transports:

      * payloads round-trip bit-exactly and the server digests the *same*
        bytes, so digests equal the in-process run's;
      * the server's ``StateStore`` does the authoritative byte
        accounting per actor — for the same run it matches
        ``SimulatedNetworkTransport``'s link accounting by construction
        (both count ``StoreEntry.nbytes`` on the same calls);
      * a missing key raises the *same* ``StoreKeyError`` (key, actor,
        nearest existing prefix), reconstructed from the server's error
        response.

    Concurrency model (the actor-runtime refactor):

      * **connection per actor** — each distinct ``actor`` string gets its
        own socket, so requests from different actors ride different TCP
        streams (the server handles each in its own thread);
      * **pipelined ``parallel()``** — inside a ``parallel()`` block,
        plain puts are sent back-to-back *without waiting for responses*
        (real in-flight concurrency over the framing).  The returned
        digest is computed client-side with the store's own digest
        function — the serde round-trip is bit-exact, so the server's
        digest must match; the match is asserted when responses drain.
        Any read op (and block exit) drains all in-flight responses
        first, so ordering is indistinguishable from the serialized
        transport;
      * **bounded reconnect** — an I/O error invalidates the connection
        and the request retries on a fresh dial with exponential backoff
        (store ops are idempotent: a replayed put re-stores the same
        bytes).  ``reconnect_attempts=0`` restores fail-fast.

    ``link_report`` mirrors the simulated transport's shape with
    client-side counters (payload bytes per actor, *real* busy seconds);
    ``wire_report`` additionally counts raw socket bytes including
    framing/envelope overhead.  ``elapsed_seconds`` is wall-clock
    actually spent blocked on the wire.
    """

    def __init__(self, address: tuple, schema: Optional[KeySchema] = None,
                 connect_timeout: float = 10.0,
                 reconnect_attempts: int = 3,
                 reconnect_backoff: float = 0.05,
                 failover: Sequence[tuple] = ()):
        self.address = (str(address[0]), int(address[1]))
        # warm-standby failover (docs/CHAOS.md): candidate store addresses
        # tried in order when the active one stops answering.  The first
        # address that dials is *promoted* (sticky): after the primary
        # dies, every subsequent dial goes straight to the standby.
        self.addresses = [self.address] + [(str(h), int(p))
                                           for h, p in failover]
        self._active = 0
        self.schema = schema or KeySchema()
        self.connect_timeout = connect_timeout
        self.reconnect_attempts = int(reconnect_attempts)
        self.reconnect_backoff = float(reconnect_backoff)
        self._conns: dict[str, _Conn] = {}
        self._conns_lock = threading.Lock()
        self._parallel_depth = 0
        self.links: dict[str, LinkStats] = defaultdict(LinkStats)
        self._elapsed = 0.0
        self._wire_up = 0
        self._wire_down = 0
        self._requests = 0

    # -- connection ------------------------------------------------------

    def _connect(self) -> socket.socket:
        """Dial with exponential backoff inside ``connect_timeout``: the
        server process may still be binding when the first request goes
        out, and a hiccuping server deserves a breather between dials.

        With ``failover`` addresses configured, every backoff round tries
        each candidate starting from the currently active one; the first
        that answers is promoted sticky (``self.address`` follows it), so
        once the fleet fails over to the warm standby it stays there
        instead of re-probing the dead primary on every reconnect."""
        deadline = time.monotonic() + self.connect_timeout
        delay = max(self.reconnect_backoff, 0.01)
        while True:
            for offset in range(len(self.addresses)):
                idx = (self._active + offset) % len(self.addresses)
                try:
                    sock = socket.create_connection(self.addresses[idx],
                                                    timeout=30.0)
                except OSError:
                    continue
                sock.settimeout(None)   # the 30s covers dialing only: a
                # large transfer on a slow link may legitimately take longer
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                if idx != self._active:
                    self._active = idx
                    self.address = self.addresses[idx]
                return sock
            now = time.monotonic()
            if now >= deadline:
                raise ConnectionError(
                    f"no store server reachable at any of {self.addresses}")
            time.sleep(min(delay, deadline - now))
            delay = min(delay * 2.0, 0.5)

    def _conn_for(self, actor: str) -> _Conn:
        with self._conns_lock:
            conn = self._conns.get(actor)
            if conn is None:
                conn = self._conns[actor] = _Conn()
            return conn

    def _invalidate(self, conn: _Conn) -> None:
        """Drop a desynchronized socket; in-flight pipelined requests stay
        queued and are replayed after the next successful dial."""
        if conn.sock is not None:
            try:
                conn.sock.close()
            except OSError:
                pass
            conn.sock = None

    def _io(self, conn: _Conn, fn):
        """Run ``fn()`` (socket I/O on ``conn``; caller holds its lock)
        with bounded reconnect: an ``OSError`` invalidates the socket,
        backs off, re-dials, replays the in-flight pipeline (idempotent
        puts) and retries."""
        attempt = 0
        while True:
            try:
                if conn.sock is None:
                    conn.sock = self._connect()
                    for entry in conn.pending:   # replay lost pipeline
                        self._send(conn, entry.req)
                return fn()
            except OSError:
                self._invalidate(conn)
                if attempt >= self.reconnect_attempts:
                    raise
                time.sleep(min(self.reconnect_backoff * (2 ** attempt), 1.0))
                attempt += 1

    def _send(self, conn: _Conn, req: dict) -> None:
        body = serde.dumps(req)
        t0 = time.monotonic()
        try:
            self._wire_up += serde.send_frame(conn.sock, body)
        finally:
            self._elapsed += time.monotonic() - t0
        self._requests += 1

    def _recv(self, conn: _Conn) -> dict:
        t0 = time.monotonic()
        try:
            resp_body = serde.recv_frame(conn.sock)
        finally:
            self._elapsed += time.monotonic() - t0
        if resp_body is None:
            # clean EOF mid-conversation: surface as a connection error so
            # _io treats it like any other I/O invalidation
            raise ConnectionError(
                f"store server {self.address} closed the connection")
        self._wire_down += len(resp_body) + 8
        return serde.loads(resp_body)

    @staticmethod
    def _check(resp: dict) -> dict:
        if resp.get("ok"):
            return resp
        if resp.get("error") == "StoreKeyError":
            raise StoreKeyError(resp["key"], resp["actor"],
                                resp["nearest_prefix"],
                                resp["nearest_count"])
        raise RuntimeError(
            f"store server error: {resp.get('error')}: "
            f"{resp.get('message', '')}")

    def _drain_conn(self, conn: _Conn) -> None:
        """Read responses for every in-flight pipelined put on ``conn``
        (caller holds its lock; callers go through :meth:`_io`)."""
        while conn.pending:
            t0 = time.monotonic()
            resp = self._check(self._recv(conn))
            entry = conn.pending.popleft()
            if resp["digest"] != entry.digest:
                raise RuntimeError(
                    f"pipelined put digest mismatch on "
                    f"{entry.req.get('key')!r}: client {entry.digest} != "
                    f"server {resp['digest']} — payload corrupted in flight")
            self._charge(entry.actor, resp["nbytes"],
                         time.monotonic() - t0, up=True)

    def _drain_all(self) -> None:
        with self._conns_lock:
            conns = list(self._conns.values())
        for conn in conns:
            with conn.lock:
                if conn.pending:
                    self._io(conn, lambda c=conn: self._drain_conn(c))

    def _request(self, req: dict, actor: str = "?") -> dict:
        conn = self._conn_for(actor)
        with conn.lock:
            def step():
                self._drain_conn(conn)
                self._send(conn, req)
                return self._recv(conn)
            return self._check(self._io(conn, step))

    def _charge(self, actor: str, nbytes: int, seconds: float,
                up: bool) -> None:
        stats = self.links[actor]
        stats.busy_seconds += seconds
        stats.transfers += 1
        if up:
            stats.up_bytes += nbytes
        else:
            stats.down_bytes += nbytes

    # -- typed plane -----------------------------------------------------

    def publish(self, msg: Message, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str:
        return self.put(msg.key(self.schema), payload, actor=actor, meta=meta)

    def fetch(self, msg: Message, actor: str = "?") -> Any:
        return self.get(msg.key(self.schema), actor=actor)

    # -- raw plane -------------------------------------------------------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        if self._parallel_depth > 0 and codec is None:
            return self._pipeline_put(key, value, actor, meta)
        t0 = time.monotonic()
        resp = self._request({"op": "put", "key": key, "value": value,
                              "actor": actor, "codec": codec, "meta": meta},
                             actor=actor)
        self._charge(actor, resp["nbytes"], time.monotonic() - t0, up=True)
        return resp["digest"]

    def _pipeline_put(self, key: str, value: Any, actor: str,
                      meta: Optional[dict]) -> str:
        """Fire-and-track put: the frame goes out now, the response is
        read at the next read op / block exit.  The digest returned is
        computed client-side with the store's own hash over the same
        bytes the server will store — the drain asserts they agree."""
        digest = _digest(value)
        req = {"op": "put", "key": key, "value": value,
               "actor": actor, "codec": None, "meta": meta}
        conn = self._conn_for(actor)
        with conn.lock:
            self._io(conn, lambda: self._send(conn, req))
            conn.pending.append(_Pending(req, actor, digest, _nbytes(value)))
        return digest

    def get(self, key: str, actor: str = "?") -> Any:
        self._drain_all()
        t0 = time.monotonic()
        resp = self._request({"op": "get", "key": key, "actor": actor},
                             actor=actor)
        self._charge(actor, resp["nbytes"], time.monotonic() - t0, up=False)
        return resp["value"]

    def exists(self, key: str) -> bool:
        self._drain_all()
        return self._request({"op": "exists", "key": key})["exists"]

    def wait_for(self, key: str, timeout: float = 0.5,
                 actor: str = "?") -> bool:
        """Block server-side until ``key`` exists (a put wakes the wait)
        or ``timeout`` expires; returns existence.  This is what makes
        pull-based actors event-driven instead of exists-polling — an
        idle actor parks a handler thread on the server's condition
        variable and costs zero CPU until its input lands."""
        self._drain_all()
        return self._request({"op": "wait", "key": key,
                              "timeout": float(timeout)},
                             actor=actor)["exists"]

    def delete_prefix(self, prefix: str) -> int:
        self._drain_all()
        return self._request({"op": "delete_prefix",
                              "prefix": prefix})["deleted"]

    def keys(self, prefix: str = "") -> list[str]:
        self._drain_all()
        return list(self._request({"op": "keys", "prefix": prefix})["keys"])

    # -- timing / accounting ---------------------------------------------

    @contextlib.contextmanager
    def parallel(self):
        """Puts inside the block pipeline on their actor's connection —
        genuinely in flight concurrently — and drain at block exit.
        Nested blocks flatten into the outermost."""
        self._parallel_depth += 1
        try:
            yield
        finally:
            self._parallel_depth -= 1
            if self._parallel_depth == 0:
                self._drain_all()

    def traffic_report(self) -> dict:
        """The *server-side* authoritative accounting."""
        self._drain_all()
        return self._request({"op": "traffic_report"})["report"]

    def link_report(self) -> dict:
        return {actor: dataclasses.asdict(s)
                for actor, s in sorted(self.links.items())}

    def wire_report(self) -> dict:
        """Raw socket bytes (payload + serde envelope + framing)."""
        return {"up_bytes": self._wire_up, "down_bytes": self._wire_down,
                "requests": self._requests}

    def elapsed_seconds(self) -> float:
        return self._elapsed

    # -- lifecycle -------------------------------------------------------

    def ping(self) -> dict:
        return self._request({"op": "ping"})

    def reset_store(self) -> None:
        """Fresh server-side store + counters (one server, many runs)."""
        self._drain_all()
        self._request({"op": "reset"})

    def stop_server(self) -> None:
        """Ask the server process to exit, then drop the connection."""
        try:
            self._drain_all()
            self._request({"op": "shutdown"})
        finally:
            self.close()

    def close(self) -> None:
        with self._conns_lock:
            conns, self._conns = self._conns, {}
        for conn in conns.values():
            with conn.lock:
                conn.pending.clear()
                if conn.sock is not None:
                    try:
                        conn.sock.close()
                    finally:
                        conn.sock = None

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
