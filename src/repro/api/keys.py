"""Versioned store-key schema: the single place key strings are minted.

Every object that transits the shared store (paper §2 'S3 bucket', Fig 6)
lives under a namespaced key.  The seed runtime scattered these as f-strings
across orchestrator/miner/validator; this module is now the only producer.
Acceptance grep: ``grep -rn '"activations/' src/repro`` must hit only this
file.

Layout (version 1 — byte-for-byte the seed layout, so digests, namespace
byte accounting and garbage-collection prefixes are unchanged):

  activations/ep{E}/t{T}/tokens          pipeline-entry token batch
  activations/ep{E}/t{T}/s{S}/m{U}       stage-S output uploaded by miner U
  activations/ep{E}/t{T}/s{S}/m{U}/grad  gradient w.r.t. that output
  weights/ep{E}/s{S}/m{U}                compressed weight upload (sharing)
  weights/ep{E}/s{S}/merged              post-butterfly DiLoCo anchor
  scores/ep{E}/v{V}/m{U}                 validator V's score for miner U

Versioning: a ``KeySchema`` is constructed at a pinned ``version``; bumping
the layout means adding a new version branch here (and a migration note in
docs/API.md) — never editing v1 in place, because validator replay and the
§5.3 transfer analysis both depend on historical keys staying parseable.
"""
from __future__ import annotations

import dataclasses
import re

SCHEMA_VERSION = 1
SUPPORTED_VERSIONS = (1,)

# namespaces (the first path segment; StateStore accounts bytes per namespace)
NS_ACTIVATIONS = "activations"
NS_WEIGHTS = "weights"
NS_SCORES = "scores"

_V1_PATTERNS = (
    ("tokens", re.compile(r"^activations/ep(?P<epoch>\d+)/t(?P<tick>\d+)/tokens$")),
    ("gradient", re.compile(
        r"^activations/ep(?P<epoch>\d+)/t(?P<tick>\d+)/s(?P<stage>\d+)"
        r"/m(?P<uid>\d+)/grad$")),
    ("activation", re.compile(
        r"^activations/ep(?P<epoch>\d+)/t(?P<tick>\d+)/s(?P<stage>\d+)"
        r"/m(?P<uid>\d+)$")),
    ("anchor", re.compile(r"^weights/ep(?P<epoch>\d+)/s(?P<stage>\d+)/merged$")),
    ("weights", re.compile(
        r"^weights/ep(?P<epoch>\d+)/s(?P<stage>\d+)/m(?P<uid>\d+)$")),
    ("score", re.compile(
        r"^scores/ep(?P<epoch>\d+)/v(?P<validator>\d+)/m(?P<uid>\d+)$")),
)


@dataclasses.dataclass(frozen=True)
class ParsedKey:
    kind: str                # tokens|activation|gradient|weights|anchor|score
    fields: dict


@dataclasses.dataclass(frozen=True)
class KeySchema:
    version: int = SCHEMA_VERSION

    def __post_init__(self):
        if self.version not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported KeySchema version {self.version}; "
                f"supported: {SUPPORTED_VERSIONS}")

    # -- activation plane ------------------------------------------------

    def tokens(self, epoch: int, tick: int) -> str:
        return f"activations/ep{epoch}/t{tick}/tokens"

    def activation(self, epoch: int, tick: int, stage: int, uid: int) -> str:
        return f"activations/ep{epoch}/t{tick}/s{stage}/m{uid}"

    def gradient(self, epoch: int, tick: int, stage: int, uid: int) -> str:
        return self.activation(epoch, tick, stage, uid) + "/grad"

    def gradient_for(self, activation_key: str) -> str:
        """Gradient key paired with an already-minted activation key
        (validator replay walks the miner's work log, which stores keys)."""
        return activation_key + "/grad"

    # -- weight plane ----------------------------------------------------

    def weight_upload(self, epoch: int, stage: int, uid: int) -> str:
        return f"weights/ep{epoch}/s{stage}/m{uid}"

    def anchor(self, epoch: int, stage: int) -> str:
        return f"weights/ep{epoch}/s{stage}/merged"

    # -- score plane -----------------------------------------------------

    def score(self, epoch: int, validator_uid: int, miner_uid: int) -> str:
        return f"scores/ep{epoch}/v{validator_uid}/m{miner_uid}"

    # -- prefixes (garbage collection, audits) ---------------------------

    def activations_prefix(self, epoch: int) -> str:
        return f"activations/ep{epoch}"

    def weights_prefix(self, epoch: int) -> str:
        return f"weights/ep{epoch}"

    # -- parsing ---------------------------------------------------------

    def parse(self, key: str) -> ParsedKey:
        """Invert a v1 key back to (kind, fields); raises ValueError on
        keys outside the schema — audit tooling uses this to walk a store."""
        for kind, pat in _V1_PATTERNS:
            m = pat.match(key)
            if m:
                return ParsedKey(kind, {k: int(v)
                                        for k, v in m.groupdict().items()})
        raise ValueError(f"key does not match KeySchema v{self.version}: "
                         f"{key!r}")
