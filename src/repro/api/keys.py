"""Versioned store-key schema: the single place key strings are minted.

Every object that transits the shared store (paper §2 'S3 bucket', Fig 6)
lives under a namespaced key.  The seed runtime scattered these as f-strings
across orchestrator/miner/validator; this module is now the only producer.
Acceptance grep: ``grep -rn '"activations/' src/repro`` must hit only this
file.

Layout (version 1 — byte-for-byte the seed layout, so digests, namespace
byte accounting and garbage-collection prefixes are unchanged):

  activations/ep{E}/t{T}/tokens          pipeline-entry token batch
  activations/ep{E}/t{T}/s{S}/m{U}       stage-S output uploaded by miner U
  activations/ep{E}/t{T}/s{S}/m{U}/grad  gradient w.r.t. that output
  weights/ep{E}/s{S}/m{U}                compressed weight upload (sharing)
  weights/ep{E}/s{S}/merged              post-butterfly DiLoCo anchor
  scores/ep{E}/v{V}/m{U}                 validator V's score for miner U

Version 2 — sharded butterfly sync (§5.1): adds shard-level keys so the
butterfly reduce runs as per-miner store-and-forward actions instead of a
central in-process loop.  Every v1 key is still minted byte-identically and
still parses; the additions are

  weights/ep{E}/s{S}/m{U}/shard{K}            miner U's upload of shard K
  weights/ep{E}/s{S}/shard{K}/reduced/m{R}    reducer R's reduced copy of
                                              shard K (two per shard: the
                                              §5.2 redundancy)

The two new kinds cannot collide with v1: the v1 weight-upload pattern is
anchored (`m{U}$`), and the reduced-copy key's second-to-last segment is
``shard{K}``/``reduced``, never ``m{U}``.

Version 3 — concurrent actor runtime (§2: miners/validators as
independent peers polling the store).  Adds the *control plane*: the keys
actors and the event-driven driver coordinate through, plus the labels
key (an actor-mode last-stage miner reads labels from the store — in the
lockstep driver they never transit it):

  activations/ep{E}/t{T}/labels     label batch for tick T (actor runtime)
  control/ep{E}/plan                the epoch plan (schedule + merge census)
  control/ep{E}/t{T}/loss           training watermark: tick T's loss,
                                    published by the last-stage miner
  control/ep{E}/snapshot/m{U}       tracked miner U's epoch-start snapshot
                                    (validator replay starts here)
  control/hb/{actor}                optional heartbeat record (the primary
                                    heartbeat channel is the actor's TCP
                                    health endpoint; see runtime/actor.py)

Version 4 — chaos/recovery plane.  One addition: plan *revisions*.  When
the event driver re-plans a dead miner's remaining ticks onto survivors
(graceful degradation, docs/CHAOS.md) it cannot rewrite the published
plan in place — the store is publish-once for control decisions just as
for weights (the CheckedStore sanitizer enforces it) and surviving
actors may be mid-read.  Instead each revision is appended under its own
key; actors poll for the next revision index while awaiting work:

  control/ep{E}/plan/r{R}           revision R (R >= 1) of epoch E's plan

The pattern cannot collide with v3: the base plan key is anchored
(``plan$``) and revisions add a ``/r{R}`` segment.

Version 5 — serve plane (inference as a pipeline workload, docs/SERVE.md).
A fresh ``serve/`` namespace: nothing here can collide with the train-era
patterns, and serve traffic GCs by round prefix without touching training
artifacts.  The driver publishes the session plan once, then one lane plan
per decode round; stages store-and-forward boundary codes per
(round, lane); tokens append under their request:

  serve/plan                        serve session spec (stages, lanes, codec)
  serve/round{N}/plan               round N's lane plan (admission/retire)
  serve/round{N}/l{L}/s{S}          stage S's boundary output for lane L
  serve/req{R}                      request R's prompt envelope
  serve/req{R}/tok{T}               token T emitted for request R
  serve/req{R}/done                 completion marker (latency stats)

Versioning: a ``KeySchema`` is constructed at a pinned ``version``; bumping
the layout means adding a new version branch here (and a migration note in
docs/API.md) — never editing v1 in place, because validator replay and the
§5.3 transfer analysis both depend on historical keys staying parseable.
Minting a v2-only kind from a v1 schema raises ``ValueError`` (a sharded
run against a v1 store is a config error, not a silent new layout); the
same applies to v3 control keys from a v1/v2 schema.
"""
from __future__ import annotations

import dataclasses
import re

SCHEMA_VERSION = 1
SUPPORTED_VERSIONS = (1, 2, 3, 4, 5)

# namespaces (the first path segment; StateStore accounts bytes per namespace)
NS_ACTIVATIONS = "activations"
NS_WEIGHTS = "weights"
NS_SCORES = "scores"
NS_CONTROL = "control"
NS_SERVE = "serve"

_V1_PATTERNS = (
    ("tokens", re.compile(r"^activations/ep(?P<epoch>\d+)/t(?P<tick>\d+)/tokens$")),
    ("gradient", re.compile(
        r"^activations/ep(?P<epoch>\d+)/t(?P<tick>\d+)/s(?P<stage>\d+)"
        r"/m(?P<uid>\d+)/grad$")),
    ("activation", re.compile(
        r"^activations/ep(?P<epoch>\d+)/t(?P<tick>\d+)/s(?P<stage>\d+)"
        r"/m(?P<uid>\d+)$")),
    ("anchor", re.compile(r"^weights/ep(?P<epoch>\d+)/s(?P<stage>\d+)/merged$")),
    ("weights", re.compile(
        r"^weights/ep(?P<epoch>\d+)/s(?P<stage>\d+)/m(?P<uid>\d+)$")),
    ("score", re.compile(
        r"^scores/ep(?P<epoch>\d+)/v(?P<validator>\d+)/m(?P<uid>\d+)$")),
)

# v2 additions are tried before the v1 patterns (they are strictly more
# specific — extra path segments — so order only matters for error text)
_V2_PATTERNS = (
    ("shard_upload", re.compile(
        r"^weights/ep(?P<epoch>\d+)/s(?P<stage>\d+)/m(?P<uid>\d+)"
        r"/shard(?P<shard>\d+)$")),
    ("shard_reduced", re.compile(
        r"^weights/ep(?P<epoch>\d+)/s(?P<stage>\d+)/shard(?P<shard>\d+)"
        r"/reduced/m(?P<reducer>\d+)$")),
)

# v3 additions: the actor runtime's control plane + the labels key.  The
# labels pattern is anchored on a literal trailing segment (like tokens),
# so it cannot collide with v1 activation keys (whose last segment is
# ``m{U}``); control/ is a fresh namespace.
_V3_PATTERNS = (
    ("labels", re.compile(
        r"^activations/ep(?P<epoch>\d+)/t(?P<tick>\d+)/labels$")),
    ("plan", re.compile(r"^control/ep(?P<epoch>\d+)/plan$")),
    ("tick_loss", re.compile(
        r"^control/ep(?P<epoch>\d+)/t(?P<tick>\d+)/loss$")),
    ("snapshot", re.compile(
        r"^control/ep(?P<epoch>\d+)/snapshot/m(?P<uid>\d+)$")),
    ("heartbeat", re.compile(r"^control/hb/(?P<actor>[A-Za-z0-9_.-]+)$")),
)

# v4 additions: plan revisions (graceful degradation after ActorDied)
_V4_PATTERNS = (
    ("plan_rev", re.compile(
        r"^control/ep(?P<epoch>\d+)/plan/r(?P<rev>\d+)$")),
)

# v5 additions: the serve plane (fresh ``serve/`` namespace; docs/SERVE.md)
_V5_PATTERNS = (
    ("serve_plan", re.compile(r"^serve/plan$")),
    ("serve_round_plan", re.compile(r"^serve/round(?P<round>\d+)/plan$")),
    ("serve_code", re.compile(
        r"^serve/round(?P<round>\d+)/l(?P<lane>\d+)/s(?P<stage>\d+)$")),
    ("serve_token", re.compile(
        r"^serve/req(?P<req>\d+)/tok(?P<index>\d+)$")),
    ("serve_done", re.compile(r"^serve/req(?P<req>\d+)/done$")),
    ("serve_request", re.compile(r"^serve/req(?P<req>\d+)$")),
)


@dataclasses.dataclass(frozen=True)
class ParsedKey:
    kind: str                # tokens|activation|gradient|weights|anchor|score
    fields: dict


@dataclasses.dataclass(frozen=True)
class KeySchema:
    version: int = SCHEMA_VERSION

    def __post_init__(self):
        if self.version not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported KeySchema version {self.version}; "
                f"supported: {SUPPORTED_VERSIONS}")

    # -- activation plane ------------------------------------------------

    def tokens(self, epoch: int, tick: int) -> str:
        return f"activations/ep{epoch}/t{tick}/tokens"

    def activation(self, epoch: int, tick: int, stage: int, uid: int) -> str:
        return f"activations/ep{epoch}/t{tick}/s{stage}/m{uid}"

    def gradient(self, epoch: int, tick: int, stage: int, uid: int) -> str:
        return self.activation(epoch, tick, stage, uid) + "/grad"

    def gradient_for(self, activation_key: str) -> str:
        """Gradient key paired with an already-minted activation key
        (validator replay walks the miner's work log, which stores keys)."""
        return activation_key + "/grad"

    # -- weight plane ----------------------------------------------------

    def weight_upload(self, epoch: int, stage: int, uid: int) -> str:
        return f"weights/ep{epoch}/s{stage}/m{uid}"

    def anchor(self, epoch: int, stage: int) -> str:
        return f"weights/ep{epoch}/s{stage}/merged"

    # -- weight plane, shard-level (version 2, §5.1 sharded uploads) -----

    def _require_v2(self, kind: str) -> None:
        if self.version < 2:
            raise ValueError(
                f"{kind} keys need KeySchema version >= 2 "
                f"(this schema is v{self.version}); construct the "
                f"transport with KeySchema(version=2) for sharded sync")

    def shard_upload(self, epoch: int, stage: int, uid: int,
                     shard: int) -> str:
        self._require_v2("shard_upload")
        return f"weights/ep{epoch}/s{stage}/m{uid}/shard{shard}"

    def shard_reduced(self, epoch: int, stage: int, shard: int,
                      reducer_uid: int) -> str:
        self._require_v2("shard_reduced")
        return (f"weights/ep{epoch}/s{stage}/shard{shard}"
                f"/reduced/m{reducer_uid}")

    # -- control plane (version 3, actor runtime) ------------------------

    def _require_v3(self, kind: str) -> None:
        if self.version < 3:
            raise ValueError(
                f"{kind} keys need KeySchema version >= 3 "
                f"(this schema is v{self.version}); the actor runtime "
                f"constructs its transport with KeySchema(version=3)")

    def labels(self, epoch: int, tick: int) -> str:
        self._require_v3("labels")
        return f"activations/ep{epoch}/t{tick}/labels"

    def plan(self, epoch: int) -> str:
        self._require_v3("plan")
        return f"control/ep{epoch}/plan"

    def tick_loss(self, epoch: int, tick: int) -> str:
        self._require_v3("tick_loss")
        return f"control/ep{epoch}/t{tick}/loss"

    def snapshot(self, epoch: int, uid: int) -> str:
        self._require_v3("snapshot")
        return f"control/ep{epoch}/snapshot/m{uid}"

    def heartbeat(self, actor: str) -> str:
        self._require_v3("heartbeat")
        return f"control/hb/{actor}"

    # -- recovery plane (version 4, chaos / graceful degradation) --------

    def _require_v4(self, kind: str) -> None:
        if self.version < 4:
            raise ValueError(
                f"{kind} keys need KeySchema version >= 4 "
                f"(this schema is v{self.version}); fault-tolerant actor "
                f"runs construct their transport with KeySchema(version=4)")

    def plan_rev(self, epoch: int, rev: int) -> str:
        """Revision ``rev`` (>= 1) of epoch's plan — published by the
        driver after re-planning a dead miner's ticks onto survivors."""
        self._require_v4("plan_rev")
        assert rev >= 1, "plan revisions start at 1 (r0 is the base plan)"
        return f"control/ep{epoch}/plan/r{rev}"

    # -- serve plane (version 5, inference workload — docs/SERVE.md) -----

    def _require_v5(self, kind: str) -> None:
        if self.version < 5:
            raise ValueError(
                f"{kind} keys need KeySchema version >= 5 "
                f"(this schema is v{self.version}); serve fleets construct "
                f"their transport with KeySchema(version=5)")

    def serve_plan(self) -> str:
        """The serve session spec (stage count, lane count, wire codec) —
        published once so serve actors can derive everything else."""
        self._require_v5("serve_plan")
        return "serve/plan"

    def serve_round_plan(self, round_: int) -> str:
        """Round ``round_``'s lane plan: which request occupies each lane
        and whether its slot is a prefill or a decode step."""
        self._require_v5("serve_round_plan")
        return f"serve/round{round_}/plan"

    def serve_code(self, round_: int, lane: int, stage: int) -> str:
        """Stage ``stage``'s boundary output for ``lane`` in one round —
        a wire code mid-chain, last-token logits on the final stage."""
        self._require_v5("serve_code")
        return f"serve/round{round_}/l{lane}/s{stage}"

    def serve_request(self, req: int) -> str:
        """Request ``req``'s prompt envelope (tokens + sampling params)."""
        self._require_v5("serve_request")
        return f"serve/req{req}"

    def serve_token(self, req: int, index: int) -> str:
        """Token ``index`` emitted for request ``req`` (0 = first sampled
        token, i.e. the prefill's continuation)."""
        self._require_v5("serve_token")
        return f"serve/req{req}/tok{index}"

    def serve_done(self, req: int) -> str:
        """Completion marker for request ``req`` (latency stats payload)."""
        self._require_v5("serve_done")
        return f"serve/req{req}/done"

    # -- score plane -----------------------------------------------------

    def score(self, epoch: int, validator_uid: int, miner_uid: int) -> str:
        return f"scores/ep{epoch}/v{validator_uid}/m{miner_uid}"

    # -- prefixes (garbage collection, audits) ---------------------------

    def activations_prefix(self, epoch: int) -> str:
        return f"activations/ep{epoch}"

    def weights_prefix(self, epoch: int) -> str:
        return f"weights/ep{epoch}"

    def stage_weights_prefix(self, epoch: int, stage: int) -> str:
        """All weight-plane keys of one (epoch, stage) — the store-side
        reduce audit walks this prefix."""
        return f"weights/ep{epoch}/s{stage}"

    def scores_prefix(self, epoch: int) -> str:
        """All score keys of one epoch — the driver's retention-window GC
        (``SwarmConfig.retain_epochs``) deletes whole epochs by prefix."""
        return f"scores/ep{epoch}"

    def control_prefix(self, epoch: int) -> str:
        """All control-plane keys of one epoch (plan, loss watermarks,
        snapshots) — the event driver GCs them with the activations."""
        self._require_v3("control_prefix")
        return f"control/ep{epoch}"

    def serve_round_prefix(self, round_: int) -> str:
        """All boundary codes + the lane plan of one decode round — the
        serve driver GCs rounds as lanes drain them."""
        self._require_v5("serve_round_prefix")
        return f"serve/round{round_}"

    def serve_request_prefix(self, req: int) -> str:
        """Everything a finished request left behind (envelope, tokens,
        done marker)."""
        self._require_v5("serve_request_prefix")
        return f"serve/req{req}"

    # -- parsing ---------------------------------------------------------

    def parse(self, key: str) -> ParsedKey:
        """Invert a key back to (kind, fields); raises ValueError on keys
        outside the schema — audit tooling uses this to walk a store.  A
        newer schema parses every older version's keys unchanged
        (historical stores stay walkable); a v1 schema rejects v2 shard
        keys and v1/v2 reject v3 control keys.  Numeric fields decode as
        ints; the heartbeat ``actor`` field stays a string."""
        patterns = _V1_PATTERNS
        if self.version >= 2:
            patterns = _V2_PATTERNS + patterns
        if self.version >= 3:
            patterns = _V3_PATTERNS + patterns
        if self.version >= 4:
            patterns = _V4_PATTERNS + patterns
        if self.version >= 5:
            patterns = _V5_PATTERNS + patterns
        for kind, pat in patterns:
            m = pat.match(key)
            if m:
                return ParsedKey(kind, {k: int(v) if v.isdigit() else v
                                        for k, v in m.groupdict().items()})
        raise ValueError(f"key does not match KeySchema v{self.version}: "
                         f"{key!r}")
